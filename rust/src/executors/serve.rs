//! `envpool serve`: the pool as a *process*, not a library call.
//!
//! A [`PoolServer`] owns a [`LeasePool`] (async scalar [`crate::pool::EnvPool`]
//! carved into per-client leases) and listens on a Unix socket. A
//! [`ShmClient`] attaches, receives a lease of `lease_size` envs, and then
//! steps them through two channels:
//!
//! - **Control** (this module): tiny length-prefixed frames over the Unix
//!   socket, reusing the [`super::ipc`] framing helpers — `Attach`,
//!   `Step{seq}`, `Reset`, `Detach`, `Heartbeat` up; `Attached`,
//!   `Refused`, `Batch{seq}`, `Error` down. Frames carry *sequence
//!   numbers only*, never payloads.
//! - **Data** ([`super::shm`]): per-lease obs/action rings in `/dev/shm`,
//!   written with one positioned write per wave. A control frame is the
//!   commit that makes a slab slot visible (two-phase, mirroring
//!   `StateBufferQueue`'s `slot_obs_mut`/`commit`).
//!
//! Backpressure is a credit scheme: wave `seq` lives in ring slot
//! `seq % ring_slots`, the client pipelines at most `ring_slots - 1`
//! waves, and the server additionally bounds queued waves per lease
//! ([`crate::pool::LeaseConfig::max_outstanding`]) — a hostile client
//! that ignores its credits gets [`Error::Lease`] replies, not memory
//! growth.
//!
//! Client death: SIGKILL closes the socket, the per-connection reader
//! thread sees EOF and releases the lease; the lease drains its in-flight
//! wave, resets its envs, and parks the fresh batch for the next client
//! (`[serve] lease N reclaimed` in the log — the chaos tests and the CI
//! serve-smoke job key on it). A heartbeat timeout optionally reaps
//! wedged-but-alive clients the same way.

use super::ipc::{read_str, read_u32, read_u64, write_str, write_u32, write_u64};
use super::shm::{ActSlab, ObsSlab, SlabSpec};
use super::traits::VectorEnv;
use crate::config::ServeConfig;
use crate::envs::registry;
use crate::envs::spec::EnvSpec;
use crate::pool::batch::BatchedTransition;
use crate::pool::lease::{LeaseConfig, LeaseEvent, LeaseId, LeasePool, Wave};
use crate::{Error, Result};
use std::collections::HashMap;
use std::io::{BufReader, Read, Write};
use std::net::Shutdown;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const TAG_ATTACH: u8 = 10;
const TAG_STEP: u8 = 11;
const TAG_RESET: u8 = 12;
const TAG_DETACH: u8 = 13;
const TAG_HEARTBEAT: u8 = 14;
const TAG_ATTACHED: u8 = 20;
const TAG_REFUSED: u8 = 21;
const TAG_BATCH: u8 = 22;
const TAG_ERROR: u8 = 23;

/// Client → server control frames.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Ctrl {
    Attach { num_envs: u32 },
    Step { seq: u64 },
    Reset,
    Detach,
    Heartbeat,
}

/// Server → client control frames.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Reply {
    Attached {
        lease: u32,
        first_env: u32,
        lease_size: u32,
        ring_slots: u32,
        obs_dim: u32,
        act_dim: u32,
        task_id: String,
        obs_path: String,
        act_path: String,
    },
    Refused { msg: String },
    Batch { seq: u64 },
    Error { msg: String },
}

impl Ctrl {
    pub(crate) fn write(&self, w: &mut impl Write) -> Result<()> {
        // Serialize into a scratch first: one write syscall per frame and
        // no partially-written frames if peers race on the stream.
        let mut b = Vec::with_capacity(16);
        match self {
            Ctrl::Attach { num_envs } => {
                b.push(TAG_ATTACH);
                write_u32(&mut b, *num_envs)?;
            }
            Ctrl::Step { seq } => {
                b.push(TAG_STEP);
                write_u64(&mut b, *seq)?;
            }
            Ctrl::Reset => b.push(TAG_RESET),
            Ctrl::Detach => b.push(TAG_DETACH),
            Ctrl::Heartbeat => b.push(TAG_HEARTBEAT),
        }
        w.write_all(&b)?;
        w.flush()?;
        Ok(())
    }

    pub(crate) fn read(r: &mut impl Read) -> Result<Ctrl> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        Ok(match tag[0] {
            TAG_ATTACH => Ctrl::Attach { num_envs: read_u32(r)? },
            TAG_STEP => Ctrl::Step { seq: read_u64(r)? },
            TAG_RESET => Ctrl::Reset,
            TAG_DETACH => Ctrl::Detach,
            TAG_HEARTBEAT => Ctrl::Heartbeat,
            t => return Err(Error::Ipc(format!("bad control tag {t}"))),
        })
    }
}

impl Reply {
    pub(crate) fn write(&self, w: &mut impl Write) -> Result<()> {
        let mut b = Vec::with_capacity(64);
        match self {
            Reply::Attached {
                lease,
                first_env,
                lease_size,
                ring_slots,
                obs_dim,
                act_dim,
                task_id,
                obs_path,
                act_path,
            } => {
                b.push(TAG_ATTACHED);
                for v in [*lease, *first_env, *lease_size, *ring_slots, *obs_dim, *act_dim] {
                    write_u32(&mut b, v)?;
                }
                write_str(&mut b, task_id)?;
                write_str(&mut b, obs_path)?;
                write_str(&mut b, act_path)?;
            }
            Reply::Refused { msg } => {
                b.push(TAG_REFUSED);
                write_str(&mut b, msg)?;
            }
            Reply::Batch { seq } => {
                b.push(TAG_BATCH);
                write_u64(&mut b, *seq)?;
            }
            Reply::Error { msg } => {
                b.push(TAG_ERROR);
                write_str(&mut b, msg)?;
            }
        }
        w.write_all(&b)?;
        w.flush()?;
        Ok(())
    }

    pub(crate) fn read(r: &mut impl Read) -> Result<Reply> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        Ok(match tag[0] {
            TAG_ATTACHED => {
                let lease = read_u32(r)?;
                let first_env = read_u32(r)?;
                let lease_size = read_u32(r)?;
                let ring_slots = read_u32(r)?;
                let obs_dim = read_u32(r)?;
                let act_dim = read_u32(r)?;
                Reply::Attached {
                    lease,
                    first_env,
                    lease_size,
                    ring_slots,
                    obs_dim,
                    act_dim,
                    task_id: read_str(r)?,
                    obs_path: read_str(r)?,
                    act_path: read_str(r)?,
                }
            }
            TAG_REFUSED => Reply::Refused { msg: read_str(r)? },
            TAG_BATCH => Reply::Batch { seq: read_u64(r)? },
            TAG_ERROR => Reply::Error { msg: read_str(r)? },
            t => return Err(Error::Ipc(format!("bad reply tag {t}"))),
        })
    }
}

struct Conn {
    id: usize,
    /// Raw handle kept for `shutdown()` (unblocks the reader thread).
    raw: UnixStream,
    /// Write half; also serializes attach-reply vs batch-publish order.
    w: Mutex<UnixStream>,
    lease: Mutex<Option<LeaseId>>,
    last_seen: Mutex<Instant>,
}

struct Shared {
    cfg: ServeConfig,
    lp: LeasePool,
    obs: Vec<Mutex<ObsSlab>>,
    act: Vec<Mutex<ActSlab>>,
    conns: Mutex<HashMap<usize, Arc<Conn>>>,
    /// lease → conn id currently bound to it.
    lease_conn: Mutex<Vec<Option<usize>>>,
    next_conn: AtomicUsize,
    shutdown: AtomicBool,
}

impl Shared {
    /// Write a completed wave into the lease's obs ring (phase one) and
    /// commit it with a `Batch` frame (phase two). No bound client —
    /// because it died between routing and publishing — just drops the
    /// wave; its lease is already on the reclaim path.
    fn publish(&self, lease: LeaseId, seq: u64, wave: &Wave) {
        {
            let mut slab = self.obs[lease].lock().unwrap();
            if let Err(e) = slab.publish(seq, &wave.obs, &wave.rew, &wave.done, &wave.trunc) {
                eprintln!("[serve] lease {lease} obs slab write failed: {e}");
                return;
            }
        }
        // Copy the binding out before touching `conns`: `release()` locks
        // these the other way around, and holding both here would invert.
        let bound = self.lease_conn.lock().unwrap()[lease];
        let conn = bound.and_then(|id| self.conns.lock().unwrap().get(&id).cloned());
        if let Some(conn) = conn {
            let mut w = conn.w.lock().unwrap();
            if Reply::Batch { seq }.write(&mut *w).is_err() {
                // Reader-side EOF will release the lease; nothing to do.
            }
        }
    }

    /// Drop a connection: unbind + reclaim its lease, close the socket.
    fn release(&self, conn: &Conn, why: &str) {
        let lease = conn.lease.lock().unwrap().take();
        self.conns.lock().unwrap().remove(&conn.id);
        let _ = conn.raw.shutdown(Shutdown::Both);
        if let Some(lease) = lease {
            self.lease_conn.lock().unwrap()[lease] = None;
            println!("[serve] lease {lease} {why}; draining and reclaiming");
            let _ = self.lp.detach(lease);
        }
    }
}

/// Handle to a running pool server; dropping it stops the server.
pub struct PoolServer {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
    stopped: bool,
}

impl PoolServer {
    /// Bind the socket, create the slab files, spawn the accept and pump
    /// threads, and return immediately.
    pub fn start(cfg: ServeConfig) -> Result<PoolServer> {
        cfg.validate()?;
        let mut lease_cfg = LeaseConfig::new(&cfg.task_id);
        lease_cfg.max_clients = cfg.max_clients;
        lease_cfg.lease_size = cfg.lease_size;
        lease_cfg.num_threads = cfg.num_threads;
        lease_cfg.seed = cfg.seed;
        lease_cfg.max_outstanding = cfg.max_outstanding();
        let lp = LeasePool::new(lease_cfg)?;
        let slab_spec = SlabSpec {
            lease_size: cfg.lease_size,
            obs_dim: lp.obs_dim(),
            act_dim: lp.act_dim(),
            ring_slots: cfg.ring_slots,
        };
        let mut obs = Vec::with_capacity(cfg.max_clients);
        let mut act = Vec::with_capacity(cfg.max_clients);
        for l in 0..cfg.max_clients {
            obs.push(Mutex::new(ObsSlab::create(&cfg.obs_slab_path(l), slab_spec)?));
            act.push(Mutex::new(ActSlab::create(&cfg.act_slab_path(l), slab_spec)?));
        }
        // A stale socket file from a dead server refuses the bind; the
        // path is ours by configuration, so replace it.
        let _ = std::fs::remove_file(&cfg.socket_path);
        let listener = UnixListener::bind(&cfg.socket_path)?;
        listener.set_nonblocking(true)?;
        println!(
            "[serve] {} serving on {} ({} leases x {} envs, ring depth {})",
            cfg.task_id,
            cfg.socket_path.display(),
            cfg.max_clients,
            cfg.lease_size,
            cfg.ring_slots,
        );
        let shared = Arc::new(Shared {
            lease_conn: Mutex::new(vec![None; cfg.max_clients]),
            cfg,
            lp,
            obs,
            act,
            conns: Mutex::new(HashMap::new()),
            next_conn: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let mut threads = Vec::new();
        {
            let shared = shared.clone();
            threads.push(std::thread::spawn(move || accept_loop(shared, listener)));
        }
        {
            let shared = shared.clone();
            threads.push(std::thread::spawn(move || pump_loop(shared)));
        }
        Ok(PoolServer { shared, threads, stopped: false })
    }

    pub fn socket_path(&self) -> &Path {
        &self.shared.cfg.socket_path
    }

    /// Total attaches served (for tests/stats).
    pub fn attaches(&self) -> u64 {
        self.shared.lp.attaches()
    }

    /// Total completed lease reclaims (for tests/stats).
    pub fn reclaims(&self) -> u64 {
        self.shared.lp.reclaims()
    }

    /// Stop the server: close every client connection, join the service
    /// threads, remove the socket and slab files.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        if self.stopped {
            return;
        }
        self.stopped = true;
        self.shared.shutdown.store(true, Ordering::SeqCst);
        let conns: Vec<Arc<Conn>> =
            self.shared.conns.lock().unwrap().values().cloned().collect();
        for c in conns {
            let _ = c.raw.shutdown(Shutdown::Both);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        let _ = std::fs::remove_file(&self.shared.cfg.socket_path);
        for l in 0..self.shared.cfg.max_clients {
            let _ = std::fs::remove_file(self.shared.cfg.obs_slab_path(l));
            let _ = std::fs::remove_file(self.shared.cfg.act_slab_path(l));
        }
    }
}

impl Drop for PoolServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn accept_loop(shared: Arc<Shared>, listener: UnixListener) {
    // Non-blocking accept + short sleeps so shutdown needs no wake-up
    // connection; attach latency of ≤25ms is irrelevant next to lease
    // reset time.
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                let id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
                let Ok(raw) = stream.try_clone() else { continue };
                let Ok(wr) = stream.try_clone() else { continue };
                let conn = Arc::new(Conn {
                    id,
                    raw,
                    w: Mutex::new(wr),
                    lease: Mutex::new(None),
                    last_seen: Mutex::new(Instant::now()),
                });
                shared.conns.lock().unwrap().insert(id, conn.clone());
                let shared = shared.clone();
                std::thread::spawn(move || reader_loop(shared, conn, stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => break,
        }
    }
}

/// Per-connection reader: control frames in, lease calls out. Any read
/// error (EOF above all — a SIGKILLed client closes its socket) releases
/// the lease.
fn reader_loop(shared: Arc<Shared>, conn: Arc<Conn>, stream: UnixStream) {
    let mut r = BufReader::new(stream);
    let mut act_buf: Vec<f32> = Vec::new();
    let why = loop {
        let ctrl = match Ctrl::read(&mut r) {
            Ok(c) => c,
            Err(_) => break "client disconnected",
        };
        *conn.last_seen.lock().unwrap() = Instant::now();
        match ctrl {
            Ctrl::Attach { num_envs } => {
                // Hold the write half across attach + the Attached reply
                // so a racing initial `Batch` (pump thread) cannot jump
                // ahead of the handshake on the stream.
                let mut w = conn.w.lock().unwrap();
                if conn.lease.lock().unwrap().is_some() {
                    let _ = Reply::Error { msg: "already attached".into() }.write(&mut *w);
                    continue;
                }
                if num_envs as usize != shared.cfg.lease_size {
                    let msg = format!(
                        "this server leases exactly {} envs per client (asked for {num_envs})",
                        shared.cfg.lease_size
                    );
                    let _ = Reply::Refused { msg }.write(&mut *w);
                    continue;
                }
                match shared.lp.attach() {
                    Err(e) => {
                        let _ = Reply::Refused { msg: e.to_string() }.write(&mut *w);
                    }
                    Ok((lease, parked)) => {
                        *conn.lease.lock().unwrap() = Some(lease);
                        shared.lease_conn.lock().unwrap()[lease] = Some(conn.id);
                        let first_env = shared.lp.first_env(lease);
                        println!(
                            "[serve] lease {lease} attached (envs {first_env}..{}) by conn {}",
                            first_env + shared.cfg.lease_size as u32,
                            conn.id
                        );
                        let reply = Reply::Attached {
                            lease: lease as u32,
                            first_env,
                            lease_size: shared.cfg.lease_size as u32,
                            ring_slots: shared.cfg.ring_slots as u32,
                            obs_dim: shared.lp.obs_dim() as u32,
                            act_dim: shared.lp.act_dim() as u32,
                            task_id: shared.cfg.task_id.clone(),
                            obs_path: shared.cfg.obs_slab_path(lease).display().to_string(),
                            act_path: shared.cfg.act_slab_path(lease).display().to_string(),
                        };
                        if reply.write(&mut *w).is_err() {
                            break "client disconnected during attach";
                        }
                        if let Some((seq, wave)) = parked {
                            // Parked initial batch: publish it right here
                            // (still under the write lock, after the
                            // handshake frame).
                            let ok = {
                                let mut slab = shared.obs[lease].lock().unwrap();
                                slab.publish(seq, &wave.obs, &wave.rew, &wave.done, &wave.trunc)
                                    .is_ok()
                            };
                            shared.lp.recycle(wave);
                            if !ok || Reply::Batch { seq }.write(&mut *w).is_err() {
                                break "client disconnected during attach";
                            }
                        }
                    }
                }
            }
            Ctrl::Step { seq } => {
                let Some(lease) = *conn.lease.lock().unwrap() else {
                    let mut w = conn.w.lock().unwrap();
                    let _ = Reply::Error { msg: "not attached".into() }.write(&mut *w);
                    continue;
                };
                // The slab header check (count + seq) rejects stale or
                // out-of-order submissions before they reach the pool.
                let res = shared.act[lease]
                    .lock()
                    .unwrap()
                    .consume(seq, &mut act_buf)
                    .and_then(|()| shared.lp.submit(lease, &act_buf));
                if let Err(e) = res {
                    let fatal = !matches!(e, Error::Lease(_) | Error::Ipc(_));
                    let mut w = conn.w.lock().unwrap();
                    let _ = Reply::Error { msg: e.to_string() }.write(&mut *w);
                    if fatal {
                        break "pool error";
                    }
                }
            }
            Ctrl::Reset => {
                let Some(lease) = *conn.lease.lock().unwrap() else {
                    let mut w = conn.w.lock().unwrap();
                    let _ = Reply::Error { msg: "not attached".into() }.write(&mut *w);
                    continue;
                };
                if let Err(e) = shared.lp.request_reset(lease) {
                    let mut w = conn.w.lock().unwrap();
                    let _ = Reply::Error { msg: e.to_string() }.write(&mut *w);
                }
            }
            Ctrl::Detach => break "client detached",
            Ctrl::Heartbeat => {}
        }
    };
    shared.release(&conn, why);
}

/// The single pool consumer: route completed waves to their clients and
/// run the heartbeat reaper.
fn pump_loop(shared: Arc<Shared>) {
    let mut events: Vec<LeaseEvent> = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        events.clear();
        if shared.lp.pump(Duration::from_millis(50), &mut events).is_err() {
            break; // pool closed/poisoned; server is done serving
        }
        for ev in events.drain(..) {
            match ev {
                LeaseEvent::Wave { lease, seq, wave } => {
                    shared.publish(lease, seq, &wave);
                    shared.lp.recycle(wave);
                }
                LeaseEvent::Reclaimed { lease } => {
                    println!(
                        "[serve] lease {lease} reclaimed: envs reset, \
                         returned to admission pool"
                    );
                }
            }
        }
        if let Some(hb) = shared.cfg.heartbeat_timeout {
            let stale: Vec<Arc<Conn>> = shared
                .conns
                .lock()
                .unwrap()
                .values()
                .filter(|c| {
                    c.lease.lock().unwrap().is_some()
                        && c.last_seen.lock().unwrap().elapsed() > hb
                })
                .cloned()
                .collect();
            for c in stale {
                println!("[serve] conn {} missed its heartbeat window", c.id);
                // EOF in the reader thread performs the actual release.
                let _ = c.raw.shutdown(Shutdown::Both);
            }
        }
    }
}

/// Run a server until `max_seconds` elapses (forever when `None`) — the
/// `envpool serve` subcommand body.
pub fn serve_blocking(cfg: ServeConfig, max_seconds: Option<u64>) -> Result<()> {
    let server = PoolServer::start(cfg)?;
    let t0 = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(200));
        if let Some(s) = max_seconds {
            if t0.elapsed() >= Duration::from_secs(s) {
                break;
            }
        }
    }
    println!(
        "[serve] shutting down after {:.0?} ({} attaches, {} reclaims)",
        t0.elapsed(),
        server.attaches(),
        server.reclaims()
    );
    server.stop();
    Ok(())
}

/// Client side of `envpool serve`: a [`VectorEnv`] whose `lease_size`
/// envs live in the server process, reached through the control socket +
/// shared-memory rings. `reset` consumes the initial reset batch the
/// server schedules at attach; `step` is `send_wave` + `recv_wave`, and
/// the two halves are public so throughput-sensitive callers can pipeline
/// up to [`ShmClient::max_outstanding`] waves.
pub struct ShmClient {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
    obs: ObsSlab,
    act: ActSlab,
    spec: EnvSpec,
    lease: u32,
    first_env: u32,
    k: usize,
    ring_slots: usize,
    /// Sequence number the next submitted wave will produce. Starts at 1:
    /// seq 0 is the initial reset wave, already in flight server-side.
    next_send: u64,
    /// Next wave sequence to consume.
    next_recv: u64,
    detached: bool,
}

impl ShmClient {
    /// Connect and attach, claiming a lease of exactly `num_envs` envs
    /// (must match the server's `lease_size`).
    pub fn attach(socket: &Path, num_envs: usize) -> Result<ShmClient> {
        let stream = UnixStream::connect(socket).map_err(|e| {
            Error::Attach(format!("cannot reach pool server at {}: {e}", socket.display()))
        })?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        Ctrl::Attach { num_envs: num_envs as u32 }.write(&mut &writer)?;
        match Reply::read(&mut reader)? {
            Reply::Refused { msg } => Err(Error::Attach(msg)),
            Reply::Attached {
                lease,
                first_env,
                lease_size,
                ring_slots,
                obs_dim,
                act_dim,
                task_id,
                obs_path,
                act_path,
            } => {
                let spec = registry::spec_for(&task_id)?;
                if spec.obs_dim() != obs_dim as usize
                    || spec.action_space.dim() != act_dim as usize
                {
                    return Err(Error::Attach(format!(
                        "server shapes ({obs_dim}, {act_dim}) disagree with this build's \
                         spec for {task_id} ({}, {})",
                        spec.obs_dim(),
                        spec.action_space.dim()
                    )));
                }
                let slab_spec = SlabSpec {
                    lease_size: lease_size as usize,
                    obs_dim: obs_dim as usize,
                    act_dim: act_dim as usize,
                    ring_slots: ring_slots as usize,
                };
                Ok(ShmClient {
                    obs: ObsSlab::open(Path::new(&obs_path), slab_spec)?,
                    act: ActSlab::open(Path::new(&act_path), slab_spec)?,
                    reader,
                    writer,
                    spec,
                    lease,
                    first_env,
                    k: lease_size as usize,
                    ring_slots: ring_slots as usize,
                    next_send: 1,
                    next_recv: 0,
                    detached: false,
                })
            }
            other => Err(Error::Attach(format!("unexpected handshake reply {other:?}"))),
        }
    }

    /// The lease this client holds.
    pub fn lease(&self) -> u32 {
        self.lease
    }

    /// Global env id of lease-local row 0.
    pub fn first_env(&self) -> u32 {
        self.first_env
    }

    /// Waves submitted (or scheduled, for the initial reset) and not yet
    /// consumed.
    pub fn outstanding(&self) -> usize {
        (self.next_send - self.next_recv) as usize
    }

    /// Most waves that may be in flight at once: one ring slot stays free
    /// so the server never overwrites a slot this client hasn't read.
    pub fn max_outstanding(&self) -> usize {
        self.ring_slots - 1
    }

    /// Pipelined half-step: write the action wave into the ring and
    /// commit it with a `Step` frame, without waiting for the result.
    pub fn send_wave(&mut self, actions: &[f32]) -> Result<()> {
        if actions.len() != self.k * self.spec.action_space.dim() {
            return Err(Error::Lease(format!(
                "action wave of {} f32s (lease wants {} envs x {} dims)",
                actions.len(),
                self.k,
                self.spec.action_space.dim()
            )));
        }
        if self.outstanding() >= self.max_outstanding() {
            return Err(Error::Lease(format!(
                "client backpressure: {} waves in flight fills the ring (depth {})",
                self.outstanding(),
                self.ring_slots
            )));
        }
        let seq = self.next_send;
        self.act.publish(seq, actions)?;
        Ctrl::Step { seq }.write(&mut &self.writer)?;
        self.next_send += 1;
        Ok(())
    }

    /// Blocking half-step: wait for the next wave's commit frame and read
    /// it out of the ring in lease-local env order.
    pub fn recv_wave(&mut self, out: &mut BatchedTransition) -> Result<()> {
        if self.outstanding() == 0 {
            return Err(Error::Lease("recv_wave with no wave in flight".into()));
        }
        loop {
            let reply = Reply::read(&mut self.reader).map_err(|e| match e {
                Error::Io(ref io)
                    if io.kind() == std::io::ErrorKind::WouldBlock
                        || io.kind() == std::io::ErrorKind::TimedOut =>
                {
                    Error::Ipc("control channel timed out waiting for a batch".into())
                }
                Error::Io(_) => Error::Ipc("pool server hung up".into()),
                other => other,
            })?;
            match reply {
                Reply::Batch { seq } => {
                    if seq != self.next_recv {
                        return Err(Error::Ipc(format!(
                            "batch seq {seq} out of order (expected {})",
                            self.next_recv
                        )));
                    }
                    self.obs.consume(seq, self.first_env, out)?;
                    self.next_recv += 1;
                    return Ok(());
                }
                Reply::Error { msg } => return Err(Error::Lease(msg)),
                other => {
                    return Err(Error::Ipc(format!("unexpected reply {other:?}")));
                }
            }
        }
    }

    /// Tell the server this client is alive without stepping (for slow
    /// actors on servers with a heartbeat timeout).
    pub fn heartbeat(&mut self) -> Result<()> {
        Ctrl::Heartbeat.write(&mut &self.writer)
    }

    /// Graceful release: the server resets the envs and re-parks the
    /// lease immediately instead of waiting for socket EOF.
    pub fn detach(mut self) -> Result<()> {
        self.detached = true;
        Ctrl::Detach.write(&mut &self.writer)
    }

    /// Test hook: die like a SIGKILLed process — no `Detach`, just a
    /// slammed socket.
    #[doc(hidden)]
    pub fn simulate_crash(mut self) {
        self.detached = true;
        let _ = self.writer.shutdown(Shutdown::Both);
    }
}

impl Drop for ShmClient {
    fn drop(&mut self) {
        if !self.detached {
            let _ = Ctrl::Detach.write(&mut &self.writer);
        }
    }
}

impl VectorEnv for ShmClient {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.k
    }

    fn reset(&mut self, out: &mut BatchedTransition) -> Result<()> {
        if self.next_recv == 0 {
            // The attach already scheduled the initial reset; its wave is
            // the one in flight.
            return self.recv_wave(out);
        }
        if self.outstanding() > 0 {
            return Err(Error::Lease("reset with waves still in flight".into()));
        }
        Ctrl::Reset.write(&mut &self.writer)?;
        self.next_send += 1;
        self.recv_wave(out)
    }

    fn step(&mut self, actions: &[f32], out: &mut BatchedTransition) -> Result<()> {
        self.send_wave(actions)?;
        self.recv_wave(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    fn test_cfg(name: &str, clients: usize, k: usize) -> ServeConfig {
        static NONCE: AtomicU32 = AtomicU32::new(0);
        let n = NONCE.fetch_add(1, Ordering::Relaxed);
        let sock = std::env::temp_dir()
            .join(format!("envpool-serve-{name}-{}-{n}.sock", std::process::id()));
        ServeConfig::new("CartPole-v1", sock)
            .max_clients(clients)
            .lease_size(k)
            .num_threads(2)
            .seed(7)
    }

    #[test]
    fn ctrl_and_reply_frames_roundtrip() {
        let frames = [
            Ctrl::Attach { num_envs: 8 },
            Ctrl::Step { seq: 42 },
            Ctrl::Reset,
            Ctrl::Detach,
            Ctrl::Heartbeat,
        ];
        for f in frames {
            let mut b = Vec::new();
            f.write(&mut b).unwrap();
            assert_eq!(Ctrl::read(&mut b.as_slice()).unwrap(), f);
        }
        let replies = [
            Reply::Attached {
                lease: 1,
                first_env: 8,
                lease_size: 8,
                ring_slots: 4,
                obs_dim: 4,
                act_dim: 1,
                task_id: "CartPole-v1".into(),
                obs_path: "/dev/shm/a.obs".into(),
                act_path: "/dev/shm/a.act".into(),
            },
            Reply::Refused { msg: "full".into() },
            Reply::Batch { seq: 7 },
            Reply::Error { msg: "nope".into() },
        ];
        for f in replies {
            let mut b = Vec::new();
            f.write(&mut b).unwrap();
            assert_eq!(Reply::read(&mut b.as_slice()).unwrap(), f);
        }
        assert!(Ctrl::read(&mut [99u8].as_slice()).is_err());
        assert!(Reply::read(&mut [99u8].as_slice()).is_err());
    }

    #[test]
    fn attach_step_detach_end_to_end() {
        let server = PoolServer::start(test_cfg("e2e", 1, 4)).unwrap();
        let mut client = ShmClient::attach(server.socket_path(), 4).unwrap();
        let mut out = client.make_output();
        client.reset(&mut out).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out.env_ids, [0, 1, 2, 3]);
        assert!(out.obs.iter().all(|x| x.is_finite()));
        for t in 0..20 {
            let acts: Vec<f32> = (0..4).map(|i| ((t + i) % 2) as f32).collect();
            client.step(&acts, &mut out).unwrap();
            assert_eq!(out.len(), 4, "step {t}");
        }
        client.detach().unwrap();
        server.stop();
    }

    #[test]
    fn wrong_lease_size_is_refused() {
        let server = PoolServer::start(test_cfg("shape", 1, 4)).unwrap();
        let err = ShmClient::attach(server.socket_path(), 64).unwrap_err();
        assert!(matches!(err, Error::Attach(_)), "got {err}");
        assert!(err.to_string().contains("leases exactly 4"), "got {err}");
        server.stop();
    }

    #[test]
    fn attach_beyond_capacity_is_refused_then_admitted_after_detach() {
        let server = PoolServer::start(test_cfg("full", 1, 2)).unwrap();
        let mut c1 = ShmClient::attach(server.socket_path(), 2).unwrap();
        let mut out = c1.make_output();
        c1.reset(&mut out).unwrap();
        let err = ShmClient::attach(server.socket_path(), 2).unwrap_err();
        assert!(err.to_string().contains("leases attached"), "got {err}");
        c1.detach().unwrap();
        // The lease drains + resets asynchronously; attach is allowed as
        // soon as the slot is unbound, and the initial batch arrives once
        // the reclaim completes.
        let mut c2 = attach_with_retry(server.socket_path(), 2);
        c2.reset(&mut out).unwrap();
        assert!(out.obs.iter().all(|x| x.is_finite()));
        server.stop();
    }

    #[test]
    fn pipelined_waves_respect_ring_credits() {
        let server = PoolServer::start(test_cfg("pipe", 1, 2)).unwrap();
        let mut c = ShmClient::attach(server.socket_path(), 2).unwrap();
        let mut out = c.make_output();
        c.reset(&mut out).unwrap();
        assert_eq!(c.max_outstanding(), 3);
        for _ in 0..3 {
            c.send_wave(&[0.0, 1.0]).unwrap();
        }
        let err = c.send_wave(&[0.0, 1.0]).unwrap_err();
        assert!(err.to_string().contains("backpressure"), "got {err}");
        for s in 1..=3u64 {
            c.recv_wave(&mut out).unwrap();
            assert_eq!(c.next_recv, s + 1);
        }
        c.detach().unwrap();
        server.stop();
    }

    pub(super) fn attach_with_retry(socket: &Path, k: usize) -> ShmClient {
        for _ in 0..100 {
            match ShmClient::attach(socket, k) {
                Ok(c) => return c,
                Err(_) => std::thread::sleep(Duration::from_millis(20)),
            }
        }
        panic!("could not attach within retry budget");
    }
}
