//! Subprocess baseline: one OS process per environment, a full barrier
//! per vectorized step, length-prefixed IPC frames in both directions —
//! the faithful Rust equivalent of `gym.vector.SubprocVecEnv`, the
//! paper's main comparison point. Its per-step cost structure
//! (synchronization + serialization + batching copy) is exactly what
//! EnvPool's queues remove.

use super::ipc::{Request, Response};
use super::traits::VectorEnv;
use crate::envs::registry;
use crate::envs::spec::EnvSpec;
use crate::pool::batch::BatchedTransition;
use crate::{Error, Result};
use std::io::{BufReader, BufWriter};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};

struct WorkerProc {
    child: Child,
    tx: BufWriter<ChildStdin>,
    rx: BufReader<ChildStdout>,
}

/// Process-per-env executor.
pub struct SubprocessExecutor {
    spec: EnvSpec,
    workers: Vec<WorkerProc>,
}

/// Locate the `envpool` binary that serves the `worker` subcommand.
/// Priority: `ENVPOOL_WORKER_BIN` env var, then next to the current exe,
/// then one directory up (unit tests run from `target/<profile>/deps`).
pub fn find_worker_bin() -> Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("ENVPOOL_WORKER_BIN") {
        return Ok(p.into());
    }
    let exe = std::env::current_exe()?;
    let dir = exe.parent().ok_or_else(|| Error::Config("no exe dir".into()))?;
    for cand in [dir.join("envpool"), dir.join("../envpool")] {
        if cand.is_file() {
            return Ok(cand);
        }
    }
    Err(Error::Config(
        "cannot find the `envpool` worker binary; build it or set ENVPOOL_WORKER_BIN".into(),
    ))
}

impl SubprocessExecutor {
    pub fn new(task_id: &str, num_envs: usize, seed: u64) -> Result<Self> {
        let bin = find_worker_bin()?;
        let spec = registry::spec_for(task_id)?;
        let mut workers = Vec::with_capacity(num_envs);
        for i in 0..num_envs {
            let mut child = Command::new(&bin)
                .args([
                    "worker",
                    "--task",
                    task_id,
                    "--seed",
                    &seed.to_string(),
                    "--env-id",
                    &i.to_string(),
                ])
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .spawn()?;
            let tx = BufWriter::new(child.stdin.take().expect("child stdin"));
            let rx = BufReader::new(child.stdout.take().expect("child stdout"));
            workers.push(WorkerProc { child, tx, rx });
        }
        Ok(SubprocessExecutor { spec, workers })
    }

    fn gather(&mut self, out: &mut BatchedTransition) -> Result<()> {
        // The batching copy Python pays: collect each worker's response
        // and copy it into the batch arrays.
        let dim = self.spec.obs_dim();
        out.obs_dim = dim;
        for (i, w) in self.workers.iter_mut().enumerate() {
            let resp: Response = Response::read(&mut w.rx)?;
            if resp.obs.len() != dim {
                return Err(Error::Ipc(format!(
                    "worker {i} sent obs of {} (expected {dim})",
                    resp.obs.len()
                )));
            }
            out.obs[i * dim..(i + 1) * dim].copy_from_slice(&resp.obs);
            out.rew[i] = resp.rew;
            out.done[i] = resp.done as u8;
            out.trunc[i] = resp.trunc as u8;
            out.env_ids[i] = i as u32;
        }
        Ok(())
    }
}

impl VectorEnv for SubprocessExecutor {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.workers.len()
    }

    fn reset(&mut self, out: &mut BatchedTransition) -> Result<()> {
        for w in &mut self.workers {
            Request::Reset.write(&mut w.tx)?;
        }
        self.gather(out)
    }

    fn step(&mut self, actions: &[f32], out: &mut BatchedTransition) -> Result<()> {
        let adim = self.spec.action_space.dim();
        // scatter: serialize + write each env's action (IPC copy #1)
        for (i, w) in self.workers.iter_mut().enumerate() {
            Request::Step(actions[i * adim..(i + 1) * adim].to_vec()).write(&mut w.tx)?;
        }
        // barrier + gather (IPC copy #2 + batching copy)
        self.gather(out)
    }
}

impl Drop for SubprocessExecutor {
    fn drop(&mut self) {
        for w in &mut self.workers {
            let _ = Request::Close.write(&mut w.tx);
        }
        for w in &mut self.workers {
            let _ = w.child.wait();
        }
    }
}
