//! Subprocess baseline: one OS process per environment, a full barrier
//! per vectorized step, length-prefixed IPC frames in both directions —
//! the faithful Rust equivalent of `gym.vector.SubprocVecEnv`, the
//! paper's main comparison point. Its per-step cost structure
//! (synchronization + serialization + batching copy) is exactly what
//! EnvPool's queues remove.

use super::ipc::{Request, Response};
use super::traits::VectorEnv;
use crate::envs::registry;
use crate::envs::spec::EnvSpec;
use crate::pool::batch::BatchedTransition;
use crate::{Error, Result};
use std::io::{BufReader, BufWriter};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::time::{Duration, Instant};

/// How long worker shutdown waits for a child to exit after `Close` (and
/// stdin EOF) before escalating to `kill()`. The serve-mode client-death
/// path reuses [`wait_child_bounded`] with the same deadline.
pub(crate) const SHUTDOWN_DEADLINE: Duration = Duration::from_secs(2);

/// Wait for `child` to exit, but never longer than `deadline`: poll
/// `try_wait` with short sleeps, then `kill()` + reap. `std`'s `Child` has
/// no timed wait, and an unbounded `wait()` hangs the caller forever on a
/// wedged child — this is the bounded primitive every teardown path uses.
pub(crate) fn wait_child_bounded(child: &mut Child, deadline: Duration) {
    let t0 = Instant::now();
    loop {
        match child.try_wait() {
            Ok(Some(_)) => return,
            Ok(None) => {
                if t0.elapsed() >= deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    return;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => {
                let _ = child.kill();
                let _ = child.wait();
                return;
            }
        }
    }
}

struct WorkerProc {
    child: Child,
    // `Option` so shutdown can drop the writer (closing the child's stdin,
    // which unblocks even a worker that ignores `Close`) before waiting.
    tx: Option<BufWriter<ChildStdin>>,
    rx: BufReader<ChildStdout>,
}

impl WorkerProc {
    fn tx(&mut self) -> Result<&mut BufWriter<ChildStdin>> {
        self.tx.as_mut().ok_or_else(|| Error::Ipc("worker stdin already closed".into()))
    }

    /// Best-effort `Close`, then drop the pipe so the child sees EOF. Does
    /// not wait — callers batch the close across all workers so children
    /// shut down in parallel, then `Drop` reaps each with a bounded wait.
    fn send_close(&mut self) {
        if let Some(mut tx) = self.tx.take() {
            let _ = Request::Close.write(&mut tx);
        }
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        // Owning cleanup: also covers workers leaked mid-`new` when a later
        // spawn fails — the partially-built Vec drops each proc here.
        self.send_close();
        wait_child_bounded(&mut self.child, SHUTDOWN_DEADLINE);
    }
}

/// Process-per-env executor.
pub struct SubprocessExecutor {
    spec: EnvSpec,
    workers: Vec<WorkerProc>,
}

/// Locate the `envpool` binary that serves the `worker` subcommand.
/// Priority: `ENVPOOL_WORKER_BIN` env var, then next to the current exe,
/// then one directory up (unit tests run from `target/<profile>/deps`).
pub fn find_worker_bin() -> Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("ENVPOOL_WORKER_BIN") {
        return Ok(p.into());
    }
    let exe = std::env::current_exe()?;
    let dir = exe.parent().ok_or_else(|| Error::Config("no exe dir".into()))?;
    for cand in [dir.join("envpool"), dir.join("../envpool")] {
        if cand.is_file() {
            return Ok(cand);
        }
    }
    Err(Error::Config(
        "cannot find the `envpool` worker binary; build it or set ENVPOOL_WORKER_BIN".into(),
    ))
}

impl SubprocessExecutor {
    pub fn new(task_id: &str, num_envs: usize, seed: u64) -> Result<Self> {
        let bin = find_worker_bin()?;
        let spec = registry::spec_for(task_id)?;
        let mut workers = Vec::with_capacity(num_envs);
        for i in 0..num_envs {
            let mut child = Command::new(&bin)
                .args([
                    "worker",
                    "--task",
                    task_id,
                    "--seed",
                    &seed.to_string(),
                    "--env-id",
                    &i.to_string(),
                ])
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .spawn()?;
            let tx = Some(BufWriter::new(child.stdin.take().expect("child stdin")));
            let rx = BufReader::new(child.stdout.take().expect("child stdout"));
            workers.push(WorkerProc { child, tx, rx });
        }
        Ok(SubprocessExecutor { spec, workers })
    }

    /// Test hook: SIGKILL worker `i` without tearing down its bookkeeping,
    /// so chaos tests can assert the next `step` fails with `Error::Ipc`
    /// instead of hanging, and that `Drop` still completes in bounded time.
    #[doc(hidden)]
    pub fn kill_worker(&mut self, i: usize) {
        let _ = self.workers[i].child.kill();
        let _ = self.workers[i].child.wait();
    }

    fn gather(&mut self, out: &mut BatchedTransition) -> Result<()> {
        // The batching copy Python pays: collect each worker's response
        // and copy it into the batch arrays. The obs length is validated
        // against the spec dim by the bounded reader *before* any payload
        // allocation, and a dead worker's EOF surfaces as `Error::Ipc`.
        let dim = self.spec.obs_dim();
        out.obs_dim = dim;
        for (i, w) in self.workers.iter_mut().enumerate() {
            let resp: Response = Response::read_bounded(&mut w.rx, dim)
                .map_err(|e| Error::Ipc(format!("worker {i} response: {e}")))?;
            if resp.obs.len() != dim {
                return Err(Error::Ipc(format!(
                    "worker {i} sent obs of {} (expected {dim})",
                    resp.obs.len()
                )));
            }
            out.obs[i * dim..(i + 1) * dim].copy_from_slice(&resp.obs);
            out.rew[i] = resp.rew;
            out.done[i] = resp.done as u8;
            out.trunc[i] = resp.trunc as u8;
            out.env_ids[i] = i as u32;
        }
        Ok(())
    }
}

impl VectorEnv for SubprocessExecutor {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.workers.len()
    }

    fn reset(&mut self, out: &mut BatchedTransition) -> Result<()> {
        for (i, w) in self.workers.iter_mut().enumerate() {
            let tx = w.tx()?;
            Request::Reset.write(tx).map_err(|e| Error::Ipc(format!("worker {i} reset: {e}")))?;
        }
        self.gather(out)
    }

    fn step(&mut self, actions: &[f32], out: &mut BatchedTransition) -> Result<()> {
        let adim = self.spec.action_space.dim();
        // scatter: serialize + write each env's action (IPC copy #1). A
        // dead worker's broken pipe is reported as Error::Ipc, not Io.
        for (i, w) in self.workers.iter_mut().enumerate() {
            let tx = w.tx()?;
            Request::Step(actions[i * adim..(i + 1) * adim].to_vec())
                .write(tx)
                .map_err(|e| Error::Ipc(format!("worker {i} step: {e}")))?;
        }
        // barrier + gather (IPC copy #2 + batching copy)
        self.gather(out)
    }
}

impl Drop for SubprocessExecutor {
    fn drop(&mut self) {
        // Fan the Close out to every worker first so they all shut down
        // concurrently; each WorkerProc then reaps its child with a
        // bounded wait (kill after SHUTDOWN_DEADLINE) in its own Drop.
        for w in &mut self.workers {
            w.send_close();
        }
    }
}
