//! Vectorized for-loop baseline: all environments stepped in the calling
//! thread through one [`VecEnv`] batch kernel. The apples-to-apples
//! comparison point for `ExecMode::Vectorized` — same SoA kernels, no
//! pool — which isolates the kernel speedup from the dispatch speedup in
//! the Table 1/2 benches.

use super::traits::VectorEnv;
use crate::envs::registry;
use crate::envs::spec::EnvSpec;
use crate::envs::vector::{SliceArena, VecEnv};
use crate::envs::Step;
use crate::pool::batch::BatchedTransition;
use crate::Result;

/// Sequential vectorized executor over a single SoA batch kernel.
pub struct VecForLoopExecutor {
    spec: EnvSpec,
    envs: Box<dyn VecEnv>,
    needs_reset: Vec<u8>,
    results: Vec<Step>,
}

impl VecForLoopExecutor {
    pub fn new(task_id: &str, num_envs: usize, seed: u64) -> Result<Self> {
        Self::new_with_lanes(task_id, num_envs, seed, crate::simd::LanePass::Auto)
    }

    /// [`Self::new`] with an explicit SIMD lane width for the kernel —
    /// the Table 2d bench pins scalar-SoA (width 1) against the lane
    /// pass this way. Every width is bitwise identical.
    pub fn new_with_lanes(
        task_id: &str,
        num_envs: usize,
        seed: u64,
        lane_pass: crate::simd::LanePass,
    ) -> Result<Self> {
        let mut envs = registry::make_vec_env(task_id, seed, 0, num_envs)?;
        envs.set_lane_pass(lane_pass);
        Ok(VecForLoopExecutor {
            spec: envs.spec().clone(),
            envs,
            needs_reset: vec![0; num_envs],
            results: vec![Step::default(); num_envs],
        })
    }
}

impl VectorEnv for VecForLoopExecutor {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn num_envs(&self) -> usize {
        self.envs.num_envs()
    }

    fn reset(&mut self, out: &mut BatchedTransition) -> Result<()> {
        let dim = self.spec.obs_dim();
        out.obs_dim = dim;
        for i in 0..self.num_envs() {
            self.envs.reset_lane(i, &mut out.obs[i * dim..(i + 1) * dim]);
            out.rew[i] = 0.0;
            out.done[i] = 0;
            out.trunc[i] = 0;
            out.env_ids[i] = i as u32;
            self.needs_reset[i] = 0;
        }
        Ok(())
    }

    fn step(&mut self, actions: &[f32], out: &mut BatchedTransition) -> Result<()> {
        let dim = self.spec.obs_dim();
        out.obs_dim = dim;
        {
            let mut arena = SliceArena::new(&mut out.obs, dim);
            self.envs.step_batch(actions, &self.needs_reset, &mut arena, &mut self.results);
        }
        for (i, s) in self.results.iter().enumerate() {
            out.rew[i] = s.reward;
            out.done[i] = s.done as u8;
            out.trunc[i] = s.truncated as u8;
            out.env_ids[i] = i as u32;
            self.needs_reset[i] = s.finished() as u8;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::executors::ForLoopExecutor;

    #[test]
    fn matches_scalar_forloop_bitwise_across_resets() {
        for task in ["CartPole-v1", "MountainCar-v0", "Pendulum-v1", "Acrobot-v1"] {
            let n = 3;
            let seed = 11;
            let mut a = ForLoopExecutor::new(task, n, seed).unwrap();
            let mut b = VecForLoopExecutor::new(task, n, seed).unwrap();
            let adim = a.spec().action_space.dim();
            let mut oa = a.make_output();
            let mut ob = b.make_output();
            a.reset(&mut oa).unwrap();
            b.reset(&mut ob).unwrap();
            assert_eq!(oa.obs, ob.obs, "{task} reset");
            for step in 0..250 {
                let actions: Vec<f32> =
                    (0..n * adim).map(|k| ((step + k) % 3) as f32 - 1.0).collect();
                a.step(&actions, &mut oa).unwrap();
                b.step(&actions, &mut ob).unwrap();
                assert_eq!(oa.rew, ob.rew, "{task} step {step} rewards");
                assert_eq!(oa.done, ob.done, "{task} step {step} dones");
                assert_eq!(oa.trunc, ob.trunc, "{task} step {step} truncs");
                assert_eq!(oa.obs, ob.obs, "{task} step {step} obs");
            }
        }
    }
}
