//! The common vectorized-environment interface all executors implement.

use crate::envs::spec::EnvSpec;
use crate::pool::batch::BatchedTransition;
use crate::pool::envpool::EnvPool;
use crate::Result;

/// A vectorized environment executor: the synchronous `gym.vector`-style
/// contract (`reset` all, `step` all), which every baseline implements
/// natively and EnvPool implements in sync mode. The PPO trainer and the
/// Figure-4 profiler drive this interface.
pub trait VectorEnv: Send {
    /// Env spec of the underlying task.
    fn spec(&self) -> &EnvSpec;

    /// Number of parallel environments.
    fn num_envs(&self) -> usize;

    /// Reset all envs; fills `out` with `num_envs` rows (env id order).
    fn reset(&mut self, out: &mut BatchedTransition) -> Result<()>;

    /// Step all envs with `actions` (row-major `[num_envs, act_dim]`,
    /// in env id order). Fills `out` with `num_envs` rows in env id
    /// order. Auto-resets finished envs on their next step.
    fn step(&mut self, actions: &[f32], out: &mut BatchedTransition) -> Result<()>;

    /// A correctly-sized output buffer.
    fn make_output(&self) -> BatchedTransition {
        BatchedTransition::with_capacity(self.num_envs(), self.spec().obs_dim())
    }
}

/// EnvPool (sync mode) seen through the common executor interface.
/// Rows are re-ordered to env-id order so all executors agree exactly.
pub struct PoolVectorEnv {
    pool: EnvPool,
    scratch: BatchedTransition,
    ids: Vec<u32>,
}

impl PoolVectorEnv {
    /// Wrap a synchronous-mode pool (`batch_size == num_envs`).
    pub fn new(pool: EnvPool) -> Result<Self> {
        if pool.config().batch_size != pool.config().num_envs {
            return Err(crate::Error::Config(
                "PoolVectorEnv requires sync mode (batch_size == num_envs)".into(),
            ));
        }
        let scratch = pool.make_output();
        let ids = (0..pool.config().num_envs as u32).collect();
        Ok(PoolVectorEnv { pool, scratch, ids })
    }

    fn reorder(&mut self, out: &mut BatchedTransition) {
        // scratch rows arrive in completion order; emit in env id order.
        let dim = self.scratch.obs_dim;
        out.obs_dim = dim;
        for k in 0..self.scratch.len() {
            let id = self.scratch.env_ids[k] as usize;
            out.obs[id * dim..(id + 1) * dim].copy_from_slice(self.scratch.obs_row(k));
            out.rew[id] = self.scratch.rew[k];
            out.done[id] = self.scratch.done[k];
            out.trunc[id] = self.scratch.trunc[k];
            out.env_ids[id] = id as u32;
        }
    }
}

impl VectorEnv for PoolVectorEnv {
    fn spec(&self) -> &EnvSpec {
        self.pool.spec()
    }

    fn num_envs(&self) -> usize {
        self.pool.config().num_envs
    }

    fn reset(&mut self, out: &mut BatchedTransition) -> Result<()> {
        let mut scratch = std::mem::take(&mut self.scratch);
        self.pool.reset_into(&mut scratch)?;
        self.scratch = scratch;
        self.reorder(out);
        Ok(())
    }

    fn step(&mut self, actions: &[f32], out: &mut BatchedTransition) -> Result<()> {
        let mut scratch = std::mem::take(&mut self.scratch);
        self.pool.step_into(actions, &self.ids, &mut scratch)?;
        self.scratch = scratch;
        self.reorder(out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::envpool::PoolConfig;

    #[test]
    fn pool_adapter_emits_env_id_order() {
        let pool = EnvPool::make(
            PoolConfig::new("CartPole-v1").num_envs(4).batch_size(4).num_threads(2).seed(1),
        )
        .unwrap();
        let mut v = PoolVectorEnv::new(pool).unwrap();
        let mut out = v.make_output();
        v.reset(&mut out).unwrap();
        assert_eq!(out.env_ids, vec![0, 1, 2, 3]);
        let actions = vec![1.0f32; 4];
        v.step(&actions, &mut out).unwrap();
        assert_eq!(out.env_ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn async_pool_rejected() {
        let pool = EnvPool::make(
            PoolConfig::new("CartPole-v1").num_envs(4).batch_size(2).num_threads(2),
        )
        .unwrap();
        assert!(PoolVectorEnv::new(pool).is_err());
    }
}
