//! Baseline vectorized-environment executors — the comparison systems of
//! the paper's Table 1 / Figure 3, rebuilt faithfully:
//!
//! - [`forloop`] — single-thread sequential stepping (`gym.vector`'s
//!   `DummyVecEnv` / the paper's "For-loop").
//! - [`subprocess`] — one OS process per environment, synchronized every
//!   step over pipes with serialized frames. This reproduces the
//!   *mechanism* that makes Python's `SubprocVecEnv` slow: a full
//!   barrier per step, two IPC copies, and a batching copy.
//! - [`sample_factory`] — Sample Factory's double-buffered asynchronous
//!   sampling: workers own fixed env sets and step them continuously,
//!   publishing completed vectors without a global barrier.
//!
//! All executors (and [`crate::pool::EnvPool`] via an adapter) implement
//! [`traits::VectorEnv`], so the PPO coordinator and the bench harnesses
//! swap them freely.
//!
//! Beyond the baselines, [`serve`] exports the pool *across process
//! boundaries*: a [`serve::PoolServer`] owns an EnvPool and leases env
//! ranges to [`serve::ShmClient`]s over a Unix control socket plus
//! shared-memory rings ([`shm`]) — `VectorEnv` for envs living in another
//! process.

pub mod traits;
pub mod forloop;
pub mod vector_forloop;
pub mod ipc;
pub mod subprocess;
pub mod sample_factory;
pub mod shm;
pub mod serve;

pub use forloop::ForLoopExecutor;
pub use sample_factory::SampleFactoryExecutor;
pub use serve::{PoolServer, ShmClient};
pub use subprocess::SubprocessExecutor;
pub use traits::{PoolVectorEnv, VectorEnv};
pub use vector_forloop::VecForLoopExecutor;
