//! Binary IPC framing for the subprocess executor: length-prefixed
//! little-endian frames over pipes. This codec is the moral equivalent of
//! the pickling `gym.vector.SubprocVecEnv` pays per step — the cost the
//! paper's EnvPool eliminates.

use crate::{Error, Result};
use std::io::{Read, Write};

/// Parent → worker messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Reset the env.
    Reset,
    /// Step with the given action lanes.
    Step(Vec<f32>),
    /// Terminate the worker.
    Close,
}

/// Worker → parent messages.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub obs: Vec<f32>,
    pub rew: f32,
    pub done: bool,
    pub trunc: bool,
}

const TAG_RESET: u8 = 1;
const TAG_STEP: u8 = 2;
const TAG_CLOSE: u8 = 3;
const TAG_RESP: u8 = 4;

/// Default element cap for peers whose frame size is not known up front
/// (tests, hand-rolled clients). 16 Mi f32s = 64 MiB of payload.
pub(crate) const MAX_F32_ELEMS: usize = 16 * 1024 * 1024;

/// Longest string accepted in a control frame (paths, task ids, errors).
pub(crate) const MAX_STR_BYTES: usize = 4096;

pub(crate) fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&(xs.len() as u32).to_le_bytes())?;
    w.write_all(&buf)?;
    Ok(())
}

/// Read a length-prefixed f32 vector, refusing to allocate anything for a
/// frame whose claimed element count exceeds `max_elems`. The byte size is
/// computed with `checked_mul` so a hostile length prefix cannot wrap the
/// allocation size on 32-bit targets.
pub(crate) fn read_f32s_bounded(r: &mut impl Read, max_elems: usize) -> Result<Vec<f32>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let n = u32::from_le_bytes(len4) as usize;
    if n > max_elems {
        return Err(Error::Ipc(format!("frame too large: {n} f32s (cap {max_elems})")));
    }
    let nbytes = n
        .checked_mul(4)
        .ok_or_else(|| Error::Ipc(format!("frame byte size overflows: {n} f32s")))?;
    let mut bytes = vec![0u8; nbytes];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub(crate) fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    if s.len() > MAX_STR_BYTES {
        return Err(Error::Ipc(format!("string frame too large: {} bytes", s.len())));
    }
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

pub(crate) fn read_str(r: &mut impl Read) -> Result<String> {
    let n = read_u32(r)? as usize;
    if n > MAX_STR_BYTES {
        return Err(Error::Ipc(format!("string frame too large: {n} bytes")));
    }
    let mut bytes = vec![0u8; n];
    r.read_exact(&mut bytes)?;
    String::from_utf8(bytes).map_err(|_| Error::Ipc("string frame is not utf-8".into()))
}

impl Request {
    pub fn write(&self, w: &mut impl Write) -> Result<()> {
        match self {
            Request::Reset => w.write_all(&[TAG_RESET])?,
            Request::Close => w.write_all(&[TAG_CLOSE])?,
            Request::Step(a) => {
                w.write_all(&[TAG_STEP])?;
                write_f32s(w, a)?;
            }
        }
        w.flush()?;
        Ok(())
    }

    pub fn read(r: &mut impl Read) -> Result<Request> {
        Self::read_bounded(r, MAX_F32_ELEMS)
    }

    /// Like [`Request::read`] but with a caller-supplied cap on the action
    /// length — the worker loop passes the spec's action dim so a corrupt
    /// or hostile length prefix is rejected before any allocation.
    pub fn read_bounded(r: &mut impl Read, max_action_elems: usize) -> Result<Request> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        Ok(match tag[0] {
            TAG_RESET => Request::Reset,
            TAG_CLOSE => Request::Close,
            TAG_STEP => Request::Step(read_f32s_bounded(r, max_action_elems)?),
            t => return Err(Error::Ipc(format!("bad request tag {t}"))),
        })
    }
}

impl Response {
    pub fn write(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&[TAG_RESP])?;
        w.write_all(&self.rew.to_le_bytes())?;
        w.write_all(&[self.done as u8, self.trunc as u8])?;
        write_f32s(w, &self.obs)?;
        w.flush()?;
        Ok(())
    }

    pub fn read(r: &mut impl Read) -> Result<Response> {
        Self::read_bounded(r, MAX_F32_ELEMS)
    }

    /// Like [`Response::read`] but the obs length claimed by the frame is
    /// validated against `max_obs_elems` (the spec's obs dim on the gather
    /// path) *before* the payload buffer is allocated.
    pub fn read_bounded(r: &mut impl Read, max_obs_elems: usize) -> Result<Response> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        if tag[0] != TAG_RESP {
            return Err(Error::Ipc(format!("bad response tag {}", tag[0])));
        }
        let mut rew4 = [0u8; 4];
        r.read_exact(&mut rew4)?;
        let mut flags = [0u8; 2];
        r.read_exact(&mut flags)?;
        Ok(Response {
            rew: f32::from_le_bytes(rew4),
            done: flags[0] != 0,
            trunc: flags[1] != 0,
            obs: read_f32s_bounded(r, max_obs_elems)?,
        })
    }
}

/// Worker-side main loop: serve one environment over `(stdin, stdout)`.
/// The `envpool worker` subcommand lands here in the child process.
pub fn worker_serve(
    task_id: &str,
    seed: u64,
    env_id: u64,
    r: &mut impl Read,
    w: &mut impl Write,
) -> Result<()> {
    let mut env = crate::envs::registry::make_env(task_id, seed, env_id)?;
    let dim = env.spec().obs_dim();
    let act_dim = env.spec().action_space.dim();
    let mut obs = vec![0.0f32; dim];
    let mut needs_reset = false;
    loop {
        match Request::read_bounded(r, act_dim)? {
            Request::Close => return Ok(()),
            Request::Reset => {
                env.reset(&mut obs);
                needs_reset = false;
                Response { obs: obs.clone(), rew: 0.0, done: false, trunc: false }.write(w)?;
            }
            Request::Step(a) => {
                if needs_reset {
                    needs_reset = false;
                    env.reset(&mut obs);
                    Response { obs: obs.clone(), rew: 0.0, done: false, trunc: false }.write(w)?;
                } else {
                    if a.len() != act_dim {
                        return Err(Error::Ipc(format!(
                            "action frame of {} f32s (expected {act_dim})",
                            a.len()
                        )));
                    }
                    let s = env.step(&a, &mut obs);
                    needs_reset = s.finished();
                    Response { obs: obs.clone(), rew: s.reward, done: s.done, trunc: s.truncated }
                        .write(w)?;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        for req in [Request::Reset, Request::Close, Request::Step(vec![1.5, -2.0, 0.0])] {
            let mut buf = Vec::new();
            req.write(&mut buf).unwrap();
            let back = Request::read(&mut buf.as_slice()).unwrap();
            assert_eq!(req, back);
        }
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response { obs: vec![0.25; 7], rew: -1.0, done: true, trunc: false };
        let mut buf = Vec::new();
        resp.write(&mut buf).unwrap();
        let back = Response::read(&mut buf.as_slice()).unwrap();
        assert_eq!(resp, back);
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(Request::read(&mut [9u8].as_slice()).is_err());
        assert!(Response::read(&mut [9u8].as_slice()).is_err());
    }

    #[test]
    fn hostile_length_prefix_rejected_before_alloc() {
        // A corrupt/hostile Step frame claiming u32::MAX elements must be
        // refused by the length check, not by a failed 16 GiB allocation
        // (or a wrapped one on 32-bit, where n * 4 overflows usize).
        let mut frame = vec![TAG_STEP];
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = Request::read(&mut frame.as_slice()).unwrap_err();
        assert!(matches!(err, Error::Ipc(_)), "got {err}");
        assert!(err.to_string().contains("frame too large"), "got {err}");

        // The old guard admitted counts up to 64 Mi elements = 256 MiB of
        // payload; a bounded reader that knows the action dim refuses
        // anything above it without reading the payload.
        let mut frame = vec![TAG_STEP];
        frame.extend_from_slice(&(64u32 * 1024 * 1024).to_le_bytes());
        let err = Request::read_bounded(&mut frame.as_slice(), 4).unwrap_err();
        assert!(err.to_string().contains("frame too large"), "got {err}");

        // Same for the response path gather() uses.
        let mut frame = vec![TAG_RESP];
        frame.extend_from_slice(&0.5f32.to_le_bytes());
        frame.extend_from_slice(&[0u8, 0u8]);
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = Response::read_bounded(&mut frame.as_slice(), 4).unwrap_err();
        assert!(err.to_string().contains("frame too large"), "got {err}");
    }

    #[test]
    fn worker_rejects_wrong_action_length() {
        // CartPole's action dim is 1; a 3-element action must error out of
        // the serve loop, not reach env.step with a bad slice.
        let mut req_bytes = Vec::new();
        Request::Reset.write(&mut req_bytes).unwrap();
        Request::Step(vec![1.0, 2.0, 3.0]).write(&mut req_bytes).unwrap();
        let mut out = Vec::new();
        let err =
            worker_serve("CartPole-v1", 0, 0, &mut req_bytes.as_slice(), &mut out).unwrap_err();
        assert!(err.to_string().contains("frame too large"), "got {err}");
    }

    #[test]
    fn str_frames_bounded_roundtrip() {
        let mut buf = Vec::new();
        write_str(&mut buf, "CartPole-v1").unwrap();
        assert_eq!(read_str(&mut buf.as_slice()).unwrap(), "CartPole-v1");
        let mut hostile = Vec::new();
        write_u32(&mut hostile, u32::MAX).unwrap();
        assert!(read_str(&mut hostile.as_slice()).is_err());
    }

    #[test]
    fn worker_serve_in_memory() {
        // Drive the worker loop over in-memory pipes (no process spawn):
        // reset, a few steps, close.
        let mut req_bytes = Vec::new();
        Request::Reset.write(&mut req_bytes).unwrap();
        for _ in 0..5 {
            Request::Step(vec![1.0]).write(&mut req_bytes).unwrap();
        }
        Request::Close.write(&mut req_bytes).unwrap();
        let mut out = Vec::new();
        worker_serve("CartPole-v1", 0, 0, &mut req_bytes.as_slice(), &mut out).unwrap();
        let mut r = out.as_slice();
        for k in 0..6 {
            let resp = Response::read(&mut r).unwrap();
            assert_eq!(resp.obs.len(), 4, "frame {k}");
        }
        assert!(Response::read(&mut r).is_err(), "no extra frames");
    }
}
