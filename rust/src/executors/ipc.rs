//! Binary IPC framing for the subprocess executor: length-prefixed
//! little-endian frames over pipes. This codec is the moral equivalent of
//! the pickling `gym.vector.SubprocVecEnv` pays per step — the cost the
//! paper's EnvPool eliminates.

use crate::{Error, Result};
use std::io::{Read, Write};

/// Parent → worker messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Reset the env.
    Reset,
    /// Step with the given action lanes.
    Step(Vec<f32>),
    /// Terminate the worker.
    Close,
}

/// Worker → parent messages.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub obs: Vec<f32>,
    pub rew: f32,
    pub done: bool,
    pub trunc: bool,
}

const TAG_RESET: u8 = 1;
const TAG_STEP: u8 = 2;
const TAG_CLOSE: u8 = 3;
const TAG_RESP: u8 = 4;

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&(xs.len() as u32).to_le_bytes())?;
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let n = u32::from_le_bytes(len4) as usize;
    if n > 64 * 1024 * 1024 {
        return Err(Error::Ipc(format!("frame too large: {n}")));
    }
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

impl Request {
    pub fn write(&self, w: &mut impl Write) -> Result<()> {
        match self {
            Request::Reset => w.write_all(&[TAG_RESET])?,
            Request::Close => w.write_all(&[TAG_CLOSE])?,
            Request::Step(a) => {
                w.write_all(&[TAG_STEP])?;
                write_f32s(w, a)?;
            }
        }
        w.flush()?;
        Ok(())
    }

    pub fn read(r: &mut impl Read) -> Result<Request> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        Ok(match tag[0] {
            TAG_RESET => Request::Reset,
            TAG_CLOSE => Request::Close,
            TAG_STEP => Request::Step(read_f32s(r)?),
            t => return Err(Error::Ipc(format!("bad request tag {t}"))),
        })
    }
}

impl Response {
    pub fn write(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&[TAG_RESP])?;
        w.write_all(&self.rew.to_le_bytes())?;
        w.write_all(&[self.done as u8, self.trunc as u8])?;
        write_f32s(w, &self.obs)?;
        w.flush()?;
        Ok(())
    }

    pub fn read(r: &mut impl Read) -> Result<Response> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        if tag[0] != TAG_RESP {
            return Err(Error::Ipc(format!("bad response tag {}", tag[0])));
        }
        let mut rew4 = [0u8; 4];
        r.read_exact(&mut rew4)?;
        let mut flags = [0u8; 2];
        r.read_exact(&mut flags)?;
        Ok(Response {
            rew: f32::from_le_bytes(rew4),
            done: flags[0] != 0,
            trunc: flags[1] != 0,
            obs: read_f32s(r)?,
        })
    }
}

/// Worker-side main loop: serve one environment over `(stdin, stdout)`.
/// The `envpool worker` subcommand lands here in the child process.
pub fn worker_serve(
    task_id: &str,
    seed: u64,
    env_id: u64,
    r: &mut impl Read,
    w: &mut impl Write,
) -> Result<()> {
    let mut env = crate::envs::registry::make_env(task_id, seed, env_id)?;
    let dim = env.spec().obs_dim();
    let mut obs = vec![0.0f32; dim];
    let mut needs_reset = false;
    loop {
        match Request::read(r)? {
            Request::Close => return Ok(()),
            Request::Reset => {
                env.reset(&mut obs);
                needs_reset = false;
                Response { obs: obs.clone(), rew: 0.0, done: false, trunc: false }.write(w)?;
            }
            Request::Step(a) => {
                if needs_reset {
                    needs_reset = false;
                    env.reset(&mut obs);
                    Response { obs: obs.clone(), rew: 0.0, done: false, trunc: false }.write(w)?;
                } else {
                    let s = env.step(&a, &mut obs);
                    needs_reset = s.finished();
                    Response { obs: obs.clone(), rew: s.reward, done: s.done, trunc: s.truncated }
                        .write(w)?;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        for req in [Request::Reset, Request::Close, Request::Step(vec![1.5, -2.0, 0.0])] {
            let mut buf = Vec::new();
            req.write(&mut buf).unwrap();
            let back = Request::read(&mut buf.as_slice()).unwrap();
            assert_eq!(req, back);
        }
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response { obs: vec![0.25; 7], rew: -1.0, done: true, trunc: false };
        let mut buf = Vec::new();
        resp.write(&mut buf).unwrap();
        let back = Response::read(&mut buf.as_slice()).unwrap();
        assert_eq!(resp, back);
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(Request::read(&mut [9u8].as_slice()).is_err());
        assert!(Response::read(&mut [9u8].as_slice()).is_err());
    }

    #[test]
    fn worker_serve_in_memory() {
        // Drive the worker loop over in-memory pipes (no process spawn):
        // reset, a few steps, close.
        let mut req_bytes = Vec::new();
        Request::Reset.write(&mut req_bytes).unwrap();
        for _ in 0..5 {
            Request::Step(vec![1.0]).write(&mut req_bytes).unwrap();
        }
        Request::Close.write(&mut req_bytes).unwrap();
        let mut out = Vec::new();
        worker_serve("CartPole-v1", 0, 0, &mut req_bytes.as_slice(), &mut out).unwrap();
        let mut r = out.as_slice();
        for k in 0..6 {
            let resp = Response::read(&mut r).unwrap();
            assert_eq!(resp.obs.len(), 4, "frame {k}");
        }
        assert!(Response::read(&mut r).is_err(), "no extra frames");
    }
}
