//! Training/benchmark coordination: the PPO loop over the AOT policy
//! ([`ppo`]), the Figure-4 profiler categories, greedy evaluation, and
//! the pure-simulation throughput driver behind Table 1 / Figure 3.

pub mod throughput;
pub mod ppo;
pub mod eval;
