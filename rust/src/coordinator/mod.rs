//! Training/benchmark coordination: the PPO loop over a pluggable
//! compute backend ([`ppo`]; AOT/PJRT artifacts or the pure-Rust native
//! fallback), the decoupled async actor–learner loop ([`async_ppo`]),
//! the Figure-4 profiler categories, greedy evaluation, and the
//! pure-simulation throughput driver behind Table 1 / Figure 3.

pub mod throughput;
pub mod ppo;
pub mod async_ppo;
pub mod eval;
