//! The decoupled async actor–learner PPO loop (`--async-train`).
//!
//! The synchronous trainer ([`super::ppo`]) steps all N envs in
//! lockstep: every rollout step waits for the slowest env, and during
//! the update phase every env sits idle. This loop runs the paper's
//! async protocol end to end instead: the pool's worker threads step
//! envs continuously, the coordinator consumes `recv` batches of M =
//! `batch_size` envs, and transitions land **per env, in arrival
//! order** in a rollout-resident [`TrajStore`] — written in place the
//! way workers write observations into `StateBufferQueue` blocks, and
//! handed to the learner zero-copy as a finished `[T, N, ...]` rollout.
//!
//! Two stores double-buffer: while the learner runs GAE + minibatch
//! updates on round `r`, envs keep filling round `r + 1` — their
//! results are drained opportunistically between minibatch updates
//! (non-blocking `recv`), and the pool's workers keep stepping in the
//! background regardless. An env that races a full round ahead of the
//! learner parks (its action is deferred) until the learner frees that
//! round's buffer, so at most two rounds are ever in flight.
//!
//! Off-policyness is *accounted, not assumed away*: every transition
//! records the minibatch-update counter its action was sampled under,
//! the summary reports mean/max staleness ([`TrainSummary`] policy-lag
//! fields), and `--max-policy-lag L` restricts mid-update draining to
//! the last `L` updates of each round (`0` = none; collection between
//! rounds and worker-side stepping still overlap). The structural
//! worst case under double-buffering is one round's worth of updates
//! (`update_epochs × num_minibatches`), reached only by transitions
//! begun a full round early.

use super::ppo::{
    compute_gae, trailing_mean, train_one_minibatch, CurvePoint, MbScratch, TrainSummary,
};
use crate::agent::sampler;
use crate::agent::traj::TrajStore;
use crate::config::{ExecutorKind, TrainConfig};
use crate::metrics::timer::{Category, TimeBreakdown};
use crate::pool::{BatchedTransition, EnvPool, PoolConfig};
use crate::rng::Pcg32;
use crate::runtime::backend::{make_backend, BackendSpec, ComputeBackend};
use crate::{Error, Result};
use std::time::{Duration, Instant};

/// Everything the per-batch driver mutates. Kept in one struct so the
/// fill loop, the mid-update drains, and the unpark step share one
/// code path ([`process_batch`]).
struct AsyncState {
    /// Double buffer: round `r` lives in `bufs[r % 2]`.
    bufs: [TrajStore; 2],
    /// Round each env's *next* `begin` belongs to (advanced when the
    /// env completes its slice of the current round).
    env_round: Vec<usize>,
    /// Deferred observation for envs a full round ahead of the
    /// learner; no action is in flight while parked.
    parked: Vec<Option<Vec<f32>>>,
    /// Round the learner is currently collecting/updating.
    learn_round: usize,
    /// Total rounds planned (step budget rounded up to whole rollouts).
    rounds: usize,
    /// Minibatch updates applied so far — the policy-version clock.
    global_updates: u32,
    ep_ret: Vec<f32>,
    completed: Vec<f32>,
    // send scratch
    act_buf: Vec<f32>,
    id_buf: Vec<u32>,
}

/// Consume one received batch: complete in-flight transitions, record
/// bootstrap values at round boundaries, and begin + send the next
/// action for every env whose round buffer is available (parking the
/// rest). One policy forward serves values and action sampling for the
/// whole batch.
fn process_batch(
    st: &mut AsyncState,
    backend: &mut dyn ComputeBackend,
    pool: &mut EnvPool,
    out: &BatchedTransition,
    bs: &BackendSpec,
    rng: &mut Pcg32,
    prof: &mut TimeBreakdown,
) -> Result<()> {
    let pol = prof.time(Category::Inference, || backend.forward(&out.obs))?;
    let (actions, logps) = if bs.continuous {
        sampler::gaussian(&pol.dist, &pol.log_std, out.len(), bs.act_dim, rng)
    } else {
        sampler::categorical(&pol.dist, out.len(), bs.act_dim, rng)
    };
    let ad = if bs.continuous { bs.act_dim } else { 1 };
    st.act_buf.clear();
    st.id_buf.clear();
    prof.time(Category::Other, || {
        for i in 0..out.len() {
            let e = out.env_ids[i] as usize;
            let r_cur = st.env_round[e];
            // 1. outcome of the env's in-flight action (absent only for
            //    the initial reset observation)
            if r_cur < st.rounds && st.bufs[r_cur % 2].pending(e) {
                st.ep_ret[e] += out.rew[i];
                if out.finished(i) {
                    st.completed.push(st.ep_ret[e]);
                    st.ep_ret[e] = 0.0;
                }
                let store = &mut st.bufs[r_cur % 2];
                store.complete(e, out.rew[i], out.done[i] != 0, out.trunc[i] != 0);
                if store.env_done(e) {
                    // this obs is s_T for round r_cur: its value is the
                    // GAE bootstrap, and the env rolls over
                    store.set_last_value(e, pol.value[i]);
                    st.env_round[e] = r_cur + 1;
                }
            }
            // 2. the env's next transition
            let r_n = st.env_round[e];
            if r_n >= st.rounds {
                continue; // step budget exhausted for this env: idle
            }
            if r_n <= st.learn_round + 1 {
                st.bufs[r_n % 2].begin(
                    e,
                    out.obs_row(i),
                    &actions[i * ad..(i + 1) * ad],
                    logps[i],
                    pol.value[i],
                    st.global_updates,
                );
                st.act_buf.extend_from_slice(&actions[i * ad..(i + 1) * ad]);
                st.id_buf.push(e as u32);
            } else {
                // a full round ahead of the learner: defer the action
                // until that round's buffer is free
                st.parked[e] = Some(out.obs_row(i).to_vec());
            }
        }
    });
    if !st.id_buf.is_empty() {
        prof.time(Category::EnvStep, || pool.send(&st.act_buf, &st.id_buf))?;
    }
    Ok(())
}

/// Resume every parked env: forward their deferred observations under
/// the *current* policy (they waited through updates, so they act on
/// the freshest parameters), begin, and send. Must run right after a
/// round's buffer is recycled — parked envs hold no in-flight action,
/// so nothing else would ever wake them.
fn unpark(
    st: &mut AsyncState,
    backend: &mut dyn ComputeBackend,
    pool: &mut EnvPool,
    bs: &BackendSpec,
    rng: &mut Pcg32,
    prof: &mut TimeBreakdown,
) -> Result<()> {
    let ids: Vec<usize> = (0..st.parked.len()).filter(|&e| st.parked[e].is_some()).collect();
    if ids.is_empty() {
        return Ok(());
    }
    let mut pobs = Vec::with_capacity(ids.len() * bs.obs_dim);
    for &e in &ids {
        pobs.extend_from_slice(st.parked[e].as_ref().expect("filtered to Some"));
    }
    let pol = prof.time(Category::Inference, || backend.forward(&pobs))?;
    let (actions, logps) = if bs.continuous {
        sampler::gaussian(&pol.dist, &pol.log_std, ids.len(), bs.act_dim, rng)
    } else {
        sampler::categorical(&pol.dist, ids.len(), bs.act_dim, rng)
    };
    let ad = if bs.continuous { bs.act_dim } else { 1 };
    st.act_buf.clear();
    st.id_buf.clear();
    for (i, &e) in ids.iter().enumerate() {
        let r = st.env_round[e];
        debug_assert!(
            r < st.rounds && r <= st.learn_round + 1,
            "parked env {e} round {r} still unavailable at unpark"
        );
        st.bufs[r % 2].begin(
            e,
            &pobs[i * bs.obs_dim..(i + 1) * bs.obs_dim],
            &actions[i * ad..(i + 1) * ad],
            logps[i],
            pol.value[i],
            st.global_updates,
        );
        st.act_buf.extend_from_slice(&actions[i * ad..(i + 1) * ad]);
        st.id_buf.push(e as u32);
        st.parked[e] = None;
    }
    prof.time(Category::EnvStep, || pool.send(&st.act_buf, &st.id_buf))?;
    Ok(())
}

/// Train per `cfg` with the decoupled loop; returns the summary and the
/// time breakdown (which gains a `recv_wait` bar — the coordinator's
/// idle time, the direct measure of actor/learner overlap).
pub fn train_async_profiled(cfg: &TrainConfig) -> Result<(TrainSummary, TimeBreakdown)> {
    cfg.validate()?;
    // validate() already demands an async executor for async_train;
    // wrapper checks mirror the sync trainer's.
    if cfg.normalize_obs_shared && cfg.executor != ExecutorKind::EnvPoolAsyncVec {
        return Err(Error::Config(format!(
            "normalize_obs_shared (pooled VecNormalize-style stats) requires the \
             envpool-async-vec executor under --async-train; executor {} only has \
             per-lane stats",
            cfg.executor
        )));
    }
    let env_spec = crate::envs::registry::spec_for_wrapped(&cfg.env_id, &cfg.wrap_config())?;
    let mut backend: Box<dyn ComputeBackend> = make_backend(cfg, &env_spec)?;
    if backend.kind() == "pjrt" && cfg.batch_size != cfg.num_envs {
        return Err(Error::Config(format!(
            "the PJRT policy artifact is compiled for a fixed batch of num_envs rows; \
             --async-train with batch_size {} < num_envs {} needs per-batch inference — \
             use --backend native, or set batch_size == num_envs",
            cfg.batch_size, cfg.num_envs
        )));
    }
    let bs = backend.spec().clone();
    let t_len = bs.num_steps;
    let n = bs.num_envs;

    let mut pool = EnvPool::make(
        PoolConfig::new(&cfg.env_id)
            .num_envs(n)
            .batch_size(cfg.batch_size)
            .num_threads(cfg.num_threads)
            .seed(cfg.seed)
            .exec_mode(cfg.executor.pool_exec_mode())
            .wrappers(cfg.wrap_config())
            .lane_pass(cfg.lane_pass),
    )?;

    let steps_per_round = (t_len * n) as u64;
    // Same round-up-to-whole-rollouts budget rule as the sync trainer.
    let rounds = cfg.total_steps.div_ceil(steps_per_round).max(1) as usize;
    let minibatch = bs.minibatch_size;
    let n_minibatches = bs.num_minibatches;
    let epochs = cfg.update_epochs;
    let updates_per_round = (epochs * n_minibatches) as u32;
    let act_cols = if bs.continuous { bs.act_dim } else { 1 };

    let mut st = AsyncState {
        bufs: [
            TrajStore::new(t_len, n, bs.obs_dim, act_cols),
            TrajStore::new(t_len, n, bs.obs_dim, act_cols),
        ],
        env_round: vec![0; n],
        parked: vec![None; n],
        learn_round: 0,
        rounds,
        global_updates: 0,
        ep_ret: vec![0.0; n],
        completed: Vec::new(),
        act_buf: Vec::new(),
        id_buf: Vec::new(),
    };
    let mut rng = Pcg32::new(cfg.seed ^ 0x6170_706f, 997);
    let mut prof = TimeBreakdown::new();
    let mut scratch = MbScratch::new();
    let mut out = pool.make_output();
    let window = 20usize;
    let mut curve = Vec::new();
    let mut best = f32::NEG_INFINITY;
    let mut lag_sum = 0.0f64;
    let mut lag_rows = 0u64;
    let mut lag_max = 0u32;

    let start = Instant::now();
    pool.async_reset();

    while st.learn_round < st.rounds {
        let li = st.learn_round % 2;

        // ---- fill: block on the pool until this round's rollout is
        //      complete (envs ahead of the learner fill the other
        //      buffer from the same recv stream) ----
        while !st.bufs[li].is_full() {
            prof.time(Category::RecvWait, || pool.recv_into(&mut out))?;
            process_batch(&mut st, &mut *backend, &mut pool, &out, &bs, &mut rng, &mut prof)?;
        }

        // ---- advantages + staleness accounting ----
        let lag = st.bufs[li].lag_stats(st.global_updates);
        lag_sum += lag.mean as f64 * st.bufs[li].buf.rows() as f64;
        lag_rows += st.bufs[li].buf.rows() as u64;
        lag_max = lag_max.max(lag.max);
        let (adv, ret) =
            compute_gae(&mut *backend, &st.bufs[li].buf, &st.bufs[li].last_values, &mut prof)?;

        // ---- updates, draining ready batches in between ----
        let lr = if cfg.anneal_lr {
            cfg.learning_rate * (1.0 - st.learn_round as f32 / st.rounds as f32)
        } else {
            cfg.learning_rate
        };
        let mut updates_done = 0u32;
        for _epoch in 0..epochs {
            let idx = st.bufs[li].buf.shuffled_indices(&mut rng);
            for k in 0..n_minibatches {
                let sl = &idx[k * minibatch..(k + 1) * minibatch];
                train_one_minibatch(
                    &mut *backend,
                    &st.bufs[li].buf,
                    &adv,
                    &ret,
                    sl,
                    lr,
                    &mut prof,
                    &mut scratch,
                    st.learn_round,
                )?;
                updates_done += 1;
                st.global_updates += 1;
                // Transitions sampled now will be `remaining` updates
                // stale when their round is learned; --max-policy-lag
                // caps that. Drains never touch bufs[li]: everything
                // arriving belongs to round learn_round + 1.
                let remaining = updates_per_round - updates_done;
                let drain_ok = match cfg.max_policy_lag {
                    None => true,
                    Some(l) => remaining <= l,
                };
                if drain_ok && remaining > 0 {
                    while pool.recv_into_timeout(&mut out, Duration::ZERO)? {
                        process_batch(
                            &mut st, &mut *backend, &mut pool, &out, &bs, &mut rng, &mut prof,
                        )?;
                    }
                }
            }
        }
        prof.bump_iteration();

        // ---- recycle the learned buffer and wake parked envs ----
        st.bufs[li].reset();
        st.learn_round += 1;
        if st.learn_round < st.rounds {
            unpark(&mut st, &mut *backend, &mut pool, &bs, &mut rng, &mut prof)?;
        }

        // ---- bookkeeping (same trailing window as the sync loop) ----
        let mean_ret = trailing_mean(&st.completed, window);
        if mean_ret.is_finite() {
            best = best.max(mean_ret);
        }
        curve.push(CurvePoint {
            env_steps: steps_per_round * st.learn_round as u64,
            wall_secs: start.elapsed().as_secs_f64(),
            mean_return: mean_ret,
        });
        if let Some(target) = cfg.target_return {
            if mean_ret.is_finite() && mean_ret >= target {
                break;
            }
        }
    }

    let wall = start.elapsed().as_secs_f64();
    let final_ret = curve.last().map(|p| p.mean_return).unwrap_or(f32::NAN);
    let ran = curve.len();
    pool.close();
    let eval_return = if cfg.eval_episodes > 0 {
        Some(super::eval::evaluate(
            &mut *backend,
            &cfg.env_id,
            cfg.eval_episodes,
            cfg.seed ^ 0x5eed,
        )?)
    } else {
        None
    };
    let summary = TrainSummary {
        env_id: cfg.env_id.clone(),
        executor: cfg.executor,
        backend: backend.kind().to_string(),
        precision: backend.precision().to_string(),
        eval_return,
        num_envs: n,
        env_steps: steps_per_round * ran as u64,
        iterations: ran,
        wall_secs: wall,
        episodes: st.completed.len(),
        final_return: final_ret,
        best_return: best,
        param_count: backend.param_count(),
        policy_lag_mean: Some(if lag_rows == 0 { 0.0 } else { (lag_sum / lag_rows as f64) as f32 }),
        policy_lag_max: Some(lag_max),
        curve,
    };
    Ok((summary, prof))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;

    fn async_cfg(env: &str, n: usize, m: usize, steps: u64) -> TrainConfig {
        TrainConfig {
            env_id: env.into(),
            executor: ExecutorKind::EnvPoolAsync,
            backend: BackendKind::Native,
            num_envs: n,
            batch_size: m,
            num_threads: 2,
            num_steps: 64,
            total_steps: steps,
            async_train: true,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn async_smoke_trains_and_reports_lag() {
        let cfg = async_cfg("CartPole-v1", 8, 4, 2 * 8 * 64);
        let (s, prof) = train_async_profiled(&cfg).unwrap();
        assert_eq!(s.backend, "native");
        assert_eq!(s.iterations, 2);
        assert_eq!(s.env_steps, 1024);
        assert!(s.episodes > 0);
        assert!(s.final_return.is_finite());
        // lag is measured, not assumed: fields populated and bounded by
        // one round of updates
        let max = s.policy_lag_max.unwrap();
        assert!(max <= (cfg.update_epochs * cfg.num_minibatches) as u32, "lag {max}");
        assert!(s.policy_lag_mean.unwrap() >= 0.0);
        assert!(s.render().contains("policy lag"), "{}", s.render());
        assert!(prof.total(Category::Training).as_nanos() > 0);
        assert!(prof.total(Category::Inference).as_nanos() > 0);
    }

    #[test]
    fn async_train_goes_through_the_main_entry_point() {
        // ppo::train dispatches on cfg.async_train, so the CLI path and
        // library callers reach this loop without a new API.
        let cfg = async_cfg("CartPole-v1", 8, 4, 8 * 64);
        let s = super::super::ppo::train(&cfg).unwrap();
        assert_eq!(s.iterations, 1);
        assert!(s.policy_lag_max.is_some());
    }

    #[test]
    fn zero_lag_bound_still_trains() {
        // --max-policy-lag 0: no draining during updates; collection
        // happens between rounds only. Must still complete the budget.
        let mut cfg = async_cfg("CartPole-v1", 8, 4, 2 * 8 * 64);
        cfg.max_policy_lag = Some(0);
        let (s, _) = train_async_profiled(&cfg).unwrap();
        assert_eq!(s.iterations, 2);
        assert_eq!(s.env_steps, 1024);
    }

    #[test]
    fn async_round_up_budget_matches_sync_rule() {
        // satellite regression parity: 1000 steps over 512-step rounds
        // trains 2 rounds / 1024 steps in the async loop too.
        let cfg = async_cfg("CartPole-v1", 8, 4, 1000);
        let (s, _) = train_async_profiled(&cfg).unwrap();
        assert_eq!(s.iterations, 2);
        assert_eq!(s.env_steps, 1024);
    }

    #[test]
    fn sync_shaped_async_pool_trains() {
        // batch_size == num_envs: one recv serves all envs; parking and
        // round-ahead paths still exercise on the drain side.
        let cfg = async_cfg("CartPole-v1", 4, 4, 4 * 64);
        let (s, _) = train_async_profiled(&cfg).unwrap();
        assert_eq!(s.iterations, 1);
    }

    #[test]
    fn continuous_control_trains_async() {
        let cfg = async_cfg("Pendulum-v1", 4, 2, 4 * 64);
        let (s, _) = train_async_profiled(&cfg).unwrap();
        assert_eq!(s.env_steps, 256);
        assert!(s.final_return.is_finite() || s.episodes == 0);
    }

    #[test]
    fn vectorized_async_executor_trains() {
        // envpool-async-vec: chunked SoA workers under the same loop.
        // 8 envs / 2 threads -> 2 chunks of 4; batch 2 <= num_chunks.
        let mut cfg = async_cfg("CartPole-v1", 8, 2, 8 * 64);
        cfg.executor = ExecutorKind::EnvPoolAsyncVec;
        let (s, _) = train_async_profiled(&cfg).unwrap();
        assert_eq!(s.iterations, 1);
        assert_eq!(s.env_steps, 512);
    }

    #[test]
    fn target_return_stops_the_async_loop_early() {
        let mut cfg = async_cfg("CartPole-v1", 8, 4, 50 * 8 * 64);
        cfg.target_return = Some(1.0);
        let (s, _) = train_async_profiled(&cfg).unwrap();
        assert!(s.iterations < 50, "ran {}", s.iterations);
        assert_eq!(s.env_steps, (s.iterations * 8 * 64) as u64);
    }
}
