//! Greedy policy evaluation: run complete episodes with argmax (discrete)
//! / mean (continuous) actions and report the mean return.
//!
//! Generalized over [`ComputeBackend`], so evaluation runs on whichever
//! compute tier is present — the PJRT artifact path *or* the pure-Rust
//! native backend (including its `--precision f32` fast path). The
//! trainer calls this after training when `--eval-episodes N` is set,
//! and `TrainSummary::eval_return` carries the result.

use crate::agent::sampler;
use crate::executors::{ForLoopExecutor, VectorEnv};
use crate::runtime::ComputeBackend;
use crate::Result;

/// Run at least `episodes` greedy episodes (across a vector of
/// `backend.spec().num_envs` bare envs — evaluation is unwrapped) and
/// return the mean episodic return.
///
/// Every env contributes a **fixed quota** of `ceil(episodes / n)`
/// episodes — its first completions — rather than stopping at the
/// first `episodes` completions pool-wide: the latter would
/// systematically select the *shortest* (for CartPole: worst) episodes
/// and bias the reported mean downward whenever envs finish at
/// different times.
pub fn evaluate(
    backend: &mut dyn ComputeBackend,
    task: &str,
    episodes: usize,
    seed: u64,
) -> Result<f32> {
    let spec = backend.spec().clone();
    let n = spec.num_envs;
    let per_env = episodes.div_ceil(n).max(1);
    let mut ex = ForLoopExecutor::new(task, n, seed)?;
    let mut out = ex.make_output();
    ex.reset(&mut out)?;
    let mut obs = out.obs.clone();
    let mut ep_ret = vec![0.0f32; n];
    let mut counts = vec![0usize; n];
    let mut returns = Vec::new();
    let max_steps = ex.spec().max_episode_steps * (per_env + 1);
    for _ in 0..max_steps {
        let pol = backend.forward(&obs)?;
        let actions = if spec.continuous {
            pol.dist.clone() // mean action
        } else {
            sampler::greedy(&pol.dist, n, spec.act_dim)
        };
        ex.step(&actions, &mut out)?;
        for i in 0..n {
            ep_ret[i] += out.rew[i];
            if out.finished(i) {
                if counts[i] < per_env {
                    returns.push(ep_ret[i]);
                    counts[i] += 1;
                }
                ep_ret[i] = 0.0;
            }
        }
        obs.copy_from_slice(&out.obs);
        if counts.iter().all(|&c| c >= per_env) {
            break;
        }
    }
    if returns.is_empty() {
        return Ok(f32::NAN);
    }
    Ok(returns.iter().sum::<f32>() / returns.len() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, Precision, TrainConfig};
    use crate::envs::registry;
    use crate::runtime::{NativeBackend, PjrtBackend};

    fn native_cfg(env: &str) -> TrainConfig {
        TrainConfig {
            env_id: env.into(),
            backend: BackendKind::Native,
            num_envs: 4,
            batch_size: 4,
            num_steps: 16,
            num_minibatches: 4,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn greedy_eval_runs_on_the_native_backend() {
        // No PJRT, no artifacts: evaluation must work in every checkout.
        let cfg = native_cfg("CartPole-v1");
        let spec = registry::spec_for("CartPole-v1").unwrap();
        let mut b = NativeBackend::make(&cfg, &spec).unwrap();
        let r = evaluate(&mut b, "CartPole-v1", 4, 7).unwrap();
        // untrained greedy policy: short episodes, return in [1, 500]
        assert!((1.0..=500.0).contains(&r), "mean return {r}");
    }

    #[test]
    fn greedy_eval_runs_on_the_f32_fast_path_and_continuous_heads() {
        let mut cfg = native_cfg("CartPole-v1");
        cfg.precision = Precision::F32;
        let spec = registry::spec_for("CartPole-v1").unwrap();
        let mut b = NativeBackend::make(&cfg, &spec).unwrap();
        let r = evaluate(&mut b, "CartPole-v1", 4, 7).unwrap();
        assert!((1.0..=500.0).contains(&r), "mean return {r}");

        // continuous: mean action, negative pendulum returns
        let cfg = native_cfg("Pendulum-v1");
        let spec = registry::spec_for("Pendulum-v1").unwrap();
        let mut b = NativeBackend::make(&cfg, &spec).unwrap();
        let r = evaluate(&mut b, "Pendulum-v1", 2, 3).unwrap();
        assert!(r.is_finite() && r <= 0.0, "pendulum return {r}");
    }

    #[test]
    fn greedy_eval_runs_cartpole_via_pjrt() {
        // The compute tier is optional (vendored stub / missing
        // artifacts): skip when absent.
        let cfg = TrainConfig { num_envs: 8, batch_size: 8, ..native_cfg("CartPole-v1") };
        let mut b = crate::compute_or_skip!(PjrtBackend::make(&cfg));
        let r = evaluate(&mut *b, "CartPole-v1", 4, 7).unwrap();
        assert!((1.0..=500.0).contains(&r), "mean return {r}");
    }
}
