//! Greedy policy evaluation: run complete episodes with argmax (discrete)
//! / mean (continuous) actions and report the mean return.

use crate::agent::params::ParamStore;
use crate::agent::sampler;
use crate::executors::{ForLoopExecutor, VectorEnv};
use crate::runtime::{Policy, Runtime};
use crate::Result;

/// Run `episodes` greedy episodes (across a vector of `policy.batch`
/// envs) and return the mean episodic return.
pub fn evaluate(
    rt: &Runtime,
    policy: &Policy,
    params: &ParamStore,
    task: &str,
    episodes: usize,
    seed: u64,
) -> Result<f32> {
    let n = policy.batch;
    let mut ex = ForLoopExecutor::new(task, n, seed)?;
    let mut out = ex.make_output();
    ex.reset(&mut out)?;
    let mut obs = out.obs.clone();
    let mut ep_ret = vec![0.0f32; n];
    let mut returns = Vec::new();
    let max_steps = ex.spec().max_episode_steps * (episodes.div_ceil(n) + 1);
    for _ in 0..max_steps {
        let pol = policy.forward(rt, params, &obs)?;
        let actions = if policy.continuous {
            pol.dist.clone() // mean action
        } else {
            sampler::greedy(&pol.dist, n, policy.act_dim)
        };
        ex.step(&actions, &mut out)?;
        for i in 0..n {
            ep_ret[i] += out.rew[i];
            if out.finished(i) {
                returns.push(ep_ret[i]);
                ep_ret[i] = 0.0;
            }
        }
        obs.copy_from_slice(&out.obs);
        if returns.len() >= episodes {
            break;
        }
    }
    if returns.is_empty() {
        return Ok(f32::NAN);
    }
    Ok(returns.iter().sum::<f32>() / returns.len() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn greedy_eval_runs_cartpole() {
        // The compute tier is optional (vendored stub / missing
        // artifacts): skip when absent.
        let rt = crate::compute_or_skip!(Runtime::cpu());
        let m = crate::compute_or_skip!(Manifest::load("artifacts"));
        let cfg = m.for_task("CartPole-v1", 8).unwrap();
        let params = ParamStore::load(&m, cfg).unwrap();
        let policy = Policy::load(&rt, cfg).unwrap();
        let r = evaluate(&rt, &policy, &params, "CartPole-v1", 4, 7).unwrap();
        // untrained greedy policy: short episodes, return in [1, 500]
        assert!(r >= 1.0 && r <= 500.0, "mean return {r}");
    }
}
