//! Pure environment-simulation throughput (paper §4.1): drive an
//! executor with random actions and count frames per second. One call =
//! one cell of Table 1 / one point of Figure 3.

use crate::config::ExecutorKind;
use crate::envs::registry;
use crate::envs::spec::ActionSpace;
use crate::executors::{
    ForLoopExecutor, SampleFactoryExecutor, SubprocessExecutor, VecForLoopExecutor, VectorEnv,
};
use crate::pool::{EnvPool, NumaPool, PoolConfig};
use crate::rng::Pcg32;
use crate::Result;
use std::time::Instant;

/// Logical shard count used by the `envpool-numa-async[-vec]` executors.
/// This container is single-socket, so the shards are logical (no node
/// binding), but the structure — independent queues/workers per shard —
/// is the paper's "EnvPool (numa+async)" row. `num_envs`, `batch_size`
/// and `num_threads` must divide by this.
pub const NUMA_NODES: usize = 2;

/// Fill `actions` with uniformly random valid actions.
pub fn random_actions(space: &ActionSpace, n: usize, rng: &mut Pcg32, actions: &mut Vec<f32>) {
    actions.clear();
    match *space {
        ActionSpace::Discrete(k) => {
            for _ in 0..n {
                actions.push(rng.below(k as u32) as f32);
            }
        }
        ActionSpace::Continuous { dim, low, high } => {
            for _ in 0..n * dim {
                actions.push(rng.range(low, high));
            }
        }
    }
}

/// Frameskip multiplier used when reporting paper-comparable "frames":
/// the paper counts Atari FPS with frameskip 4 and MuJoCo with 5 substeps.
pub fn frame_multiplier(task: &str) -> u64 {
    if task.contains("Pong") || task.contains("Breakout") {
        crate::envs::atari::FRAMESKIP as u64
    } else if task.ends_with("-v4") || task == "cheetah_run" {
        crate::envs::mujoco::FRAME_SKIP as u64
    } else {
        1
    }
}

/// Run `steps` env steps under the named executor, returning frames/s
/// (env steps × frameskip per second, the paper's metric). SIMD lane
/// width resolves to `auto` — see [`run_throughput_lanes`] to pin it.
pub fn run_throughput(
    task: &str,
    executor: &str,
    num_envs: usize,
    batch_size: usize,
    threads: usize,
    steps: u64,
    seed: u64,
) -> Result<f64> {
    run_throughput_lanes(
        task,
        executor,
        num_envs,
        batch_size,
        threads,
        steps,
        seed,
        crate::simd::LanePass::Auto,
    )
}

/// [`run_throughput`] with an explicit SIMD lane width for the
/// vectorized kernels (`--lane-width` on the CLI; the Table 2d bench
/// pins widths 1/4/8 through this). Scalar executors ignore it.
#[allow(clippy::too_many_arguments)]
pub fn run_throughput_lanes(
    task: &str,
    executor: &str,
    num_envs: usize,
    batch_size: usize,
    threads: usize,
    steps: u64,
    seed: u64,
    lane_pass: crate::simd::LanePass,
) -> Result<f64> {
    let kind: ExecutorKind = executor.parse()?;
    let spec = registry::spec_for(task)?;
    let mut rng = Pcg32::new(seed ^ 0xBE7C4, 0);
    let mut actions = Vec::new();
    let mult = frame_multiplier(task) as f64;

    let fps = match kind {
        ExecutorKind::ForLoop => {
            let mut ex = ForLoopExecutor::new(task, num_envs, seed)?;
            time_sync_executor(&mut ex, steps, &mut rng, &mut actions)?
        }
        ExecutorKind::ForLoopVec => {
            let mut ex = VecForLoopExecutor::new_with_lanes(task, num_envs, seed, lane_pass)?;
            time_sync_executor(&mut ex, steps, &mut rng, &mut actions)?
        }
        ExecutorKind::Subprocess => {
            let mut ex = SubprocessExecutor::new(task, num_envs, seed)?;
            time_sync_executor(&mut ex, steps, &mut rng, &mut actions)?
        }
        ExecutorKind::EnvPoolSync | ExecutorKind::EnvPoolSyncVec => {
            let pool = EnvPool::make(
                PoolConfig::new(task)
                    .num_envs(num_envs)
                    .sync()
                    .num_threads(threads)
                    .seed(seed)
                    .exec_mode(kind.pool_exec_mode())
                    .lane_pass(lane_pass),
            )?;
            let mut ex = crate::executors::PoolVectorEnv::new(pool)?;
            time_sync_executor(&mut ex, steps, &mut rng, &mut actions)?
        }
        ExecutorKind::EnvPoolAsync | ExecutorKind::EnvPoolAsyncVec => {
            let mut pool = EnvPool::make(
                PoolConfig::new(task)
                    .num_envs(num_envs)
                    .batch_size(batch_size)
                    .num_threads(threads)
                    .seed(seed)
                    .exec_mode(kind.pool_exec_mode())
                    .lane_pass(lane_pass),
            )?;
            pool.async_reset();
            let mut out = pool.make_output();
            let mut done_steps = 0u64;
            let t0 = Instant::now();
            while done_steps < steps {
                pool.recv_into(&mut out)?;
                random_actions(&spec.action_space, out.len(), &mut rng, &mut actions);
                pool.send(&actions, &out.env_ids.clone())?;
                done_steps += out.len() as u64;
            }
            done_steps as f64 / t0.elapsed().as_secs_f64()
        }
        ExecutorKind::EnvPoolNumaAsync | ExecutorKind::EnvPoolNumaAsyncVec => {
            let mut pool = NumaPool::make(
                PoolConfig::new(task)
                    .num_envs(num_envs)
                    .batch_size(batch_size)
                    .num_threads(threads)
                    .seed(seed)
                    .exec_mode(kind.pool_exec_mode())
                    .lane_pass(lane_pass),
                NUMA_NODES,
            )?;
            pool.async_reset();
            let mut outs = pool.make_outputs();
            let mut ids: Vec<u32> = Vec::new();
            let mut done_steps = 0u64;
            let t0 = Instant::now();
            while done_steps < steps {
                pool.recv_all(&mut outs)?;
                ids.clear();
                for o in &outs {
                    ids.extend_from_slice(&o.env_ids);
                }
                random_actions(&spec.action_space, ids.len(), &mut rng, &mut actions);
                pool.send(&actions, &ids)?;
                done_steps += ids.len() as u64;
            }
            done_steps as f64 / t0.elapsed().as_secs_f64()
        }
        ExecutorKind::SampleFactory | ExecutorKind::SampleFactoryVec => {
            let workers = threads.max(1);
            let mut ex = if kind == ExecutorKind::SampleFactoryVec {
                SampleFactoryExecutor::new_vectorized_with_lanes(
                    task, num_envs, workers, seed, lane_pass,
                )?
            } else {
                SampleFactoryExecutor::new(task, num_envs, workers, seed)?
            };
            let mut out = ex.make_output();
            let mut done_steps = 0u64;
            let t0 = Instant::now();
            while done_steps < steps {
                let w = ex.recv_into(&mut out);
                random_actions(&spec.action_space, out.len(), &mut rng, &mut actions);
                ex.send(w, &actions);
                done_steps += out.len() as u64;
            }
            done_steps as f64 / t0.elapsed().as_secs_f64()
        }
    };
    Ok(fps * mult)
}

/// Run `steps` env steps over a heterogeneous scenario pool
/// (`--scenario` on the bench CLI; the Table 2h mixed-pool number).
/// The executor must be one of the synchronous pool kinds; frames are
/// weighted per env by its group's frameskip (a Pong lane contributes
/// 4 frames per step, a CartPole lane 1 — same accounting the
/// homogeneous table rows use).
pub fn run_throughput_scenario(
    sc: &crate::config::ScenarioConfig,
    executor: &str,
    threads: usize,
    steps: u64,
    seed: u64,
    lane_pass: crate::simd::LanePass,
) -> Result<f64> {
    let kind: ExecutorKind = executor.parse()?;
    if !matches!(kind, ExecutorKind::EnvPoolSync | ExecutorKind::EnvPoolSyncVec) {
        return Err(crate::Error::Config(format!(
            "scenario throughput runs behind the synchronous pool facade; executor \
             {kind} cannot drive it — use envpool-sync or envpool-sync-vec"
        )));
    }
    let pool = EnvPool::make(
        PoolConfig::new("scenario")
            .scenario(sc.clone())
            .sync()
            .num_threads(threads)
            .seed(seed)
            .exec_mode(kind.pool_exec_mode())
            .lane_pass(lane_pass),
    )?;
    // Per-env frame weight and action space, from the group views.
    let spec = pool.spec().clone();
    let mut mult = Vec::with_capacity(sc.num_envs());
    for g in &spec.groups {
        mult.extend(std::iter::repeat(frame_multiplier(&g.task_id)).take(g.count));
    }
    let mut ex = crate::executors::PoolVectorEnv::new(pool)?;
    let mut rng = Pcg32::new(seed ^ 0xBE7C4, 0);
    let mut out = ex.make_output();
    ex.reset(&mut out)?;
    let n = ex.num_envs();
    let space = spec.action_space.clone();
    let mut actions = Vec::new();
    let mut done_steps = 0u64;
    let mut frames = 0u64;
    let frames_per_round: u64 = mult.iter().sum();
    let t0 = Instant::now();
    while done_steps < steps {
        random_actions(&space, n, &mut rng, &mut actions);
        ex.step(&actions, &mut out)?;
        done_steps += n as u64;
        frames += frames_per_round;
    }
    Ok(frames as f64 / t0.elapsed().as_secs_f64())
}

fn time_sync_executor(
    ex: &mut dyn VectorEnv,
    steps: u64,
    rng: &mut Pcg32,
    actions: &mut Vec<f32>,
) -> Result<f64> {
    let mut out = ex.make_output();
    ex.reset(&mut out)?;
    let space = ex.spec().action_space.clone();
    let n = ex.num_envs();
    let mut done_steps = 0u64;
    let t0 = Instant::now();
    while done_steps < steps {
        random_actions(&space, n, rng, actions);
        ex.step(actions, &mut out)?;
        done_steps += n as u64;
    }
    Ok(done_steps as f64 / t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_actions_respect_spaces() {
        let mut rng = Pcg32::new(0, 0);
        let mut a = Vec::new();
        random_actions(&ActionSpace::Discrete(4), 100, &mut rng, &mut a);
        assert_eq!(a.len(), 100);
        assert!(a.iter().all(|&x| (0.0..4.0).contains(&x) && x.fract() == 0.0));
        random_actions(&ActionSpace::Continuous { dim: 3, low: -1.0, high: 1.0 }, 10, &mut rng, &mut a);
        assert_eq!(a.len(), 30);
        assert!(a.iter().all(|&x| (-1.0..1.0).contains(&x)));
    }

    #[test]
    fn frame_multipliers() {
        assert_eq!(frame_multiplier("Pong-v5"), 4);
        assert_eq!(frame_multiplier("Ant-v4"), 5);
        assert_eq!(frame_multiplier("cheetah_run"), 5);
        assert_eq!(frame_multiplier("CartPole-v1"), 1);
    }

    #[test]
    fn forced_lane_widths_run_and_stay_positive() {
        use crate::simd::LanePass;
        for lp in [LanePass::Scalar, LanePass::Width4, LanePass::Width8] {
            let fps = run_throughput_lanes(
                "CartPole-v1", "forloop-vec", 6, 6, 1, 300, 0, lp,
            )
            .unwrap();
            assert!(fps > 0.0, "{lp}: {fps}");
            let fps = run_throughput_lanes(
                "CartPole-v1", "envpool-sync-vec", 6, 6, 2, 300, 0, lp,
            )
            .unwrap();
            assert!(fps > 0.0, "{lp} pool: {fps}");
        }
    }

    #[test]
    fn scenario_throughput_runs_and_rejects_async_executors() {
        let sc = crate::config::ScenarioConfig::parse(
            "[group]\ntask = CartPole-v1\ncount = 3\n\
             [group]\ntask = Pendulum-v1\ncount = 2\n",
        )
        .unwrap();
        for ex in ["envpool-sync", "envpool-sync-vec"] {
            let fps =
                run_throughput_scenario(&sc, ex, 2, 200, 0, crate::simd::LanePass::Auto).unwrap();
            assert!(fps > 0.0, "{ex}: {fps}");
        }
        assert!(
            run_throughput_scenario(&sc, "envpool-async", 2, 100, 0, crate::simd::LanePass::Auto)
                .is_err()
        );
    }

    #[test]
    fn throughput_runs_for_each_in_process_executor() {
        for ex in [
            "forloop",
            "forloop-vec",
            "envpool-sync",
            "envpool-sync-vec",
            "envpool-async",
            "envpool-async-vec",
            "envpool-numa-async",
            "envpool-numa-async-vec",
            "sample-factory",
            "sample-factory-vec",
        ] {
            let fps = run_throughput("CartPole-v1", ex, 4, 2, 2, 400, 0).unwrap();
            assert!(fps > 0.0, "{ex}: {fps}");
        }
    }
}
