//! The PPO training coordinator: EnvPool (or a baseline executor) on the
//! environment side, a [`ComputeBackend`] on the compute side (AOT
//! JAX/Pallas executables via PJRT, or the pure-Rust native fallback),
//! everything orchestrated from Rust.
//!
//! Semantics follow CleanRL's PPO (the paper's reference integration):
//! vectorized sync rollouts of `num_steps`, GAE with done|truncated
//! merged (CleanRL treats both as episode ends), minibatch shuffling per
//! epoch, linear lr annealing, and EnvPool-style auto-reset where the
//! action after a terminal transition produces the reset observation as
//! a zero-reward step — exactly what real EnvPool integrations see.

use crate::agent::rollout::RolloutBuffer;
use crate::agent::sampler;
use crate::config::{ExecutorKind, TrainConfig};
use crate::executors::{
    ForLoopExecutor, PoolVectorEnv, SubprocessExecutor, VecForLoopExecutor, VectorEnv,
};
use crate::metrics::timer::{Category, TimeBreakdown};
use crate::pool::{EnvPool, PoolConfig};
use crate::rng::Pcg32;
use crate::runtime::backend::{make_backend, ComputeBackend};
use crate::runtime::trainer_exec::Minibatch;
use crate::{Error, Result};
use std::time::Instant;

/// One point of a learning curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    /// Cumulative environment steps.
    pub env_steps: u64,
    /// Wall-clock seconds since training start.
    pub wall_secs: f64,
    /// Mean episodic return over the trailing window.
    pub mean_return: f32,
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainSummary {
    pub env_id: String,
    pub executor: ExecutorKind,
    /// Compute backend that ran the updates (`"pjrt"` or `"native"`).
    pub backend: String,
    /// Arithmetic the backend computed in (`"f64"` native reference,
    /// `"f32"` native fast path / PJRT artifacts).
    pub precision: String,
    /// Mean greedy-evaluation return (`--eval-episodes N`, run after
    /// training on the same backend); `None` when evaluation was off.
    pub eval_return: Option<f32>,
    pub num_envs: usize,
    pub env_steps: u64,
    pub iterations: usize,
    pub wall_secs: f64,
    pub episodes: usize,
    pub final_return: f32,
    pub best_return: f32,
    pub param_count: usize,
    /// Mean staleness (in minibatch-update units) of the behaviour
    /// policy behind the learner over all trained-on transitions;
    /// `None` for the synchronous loop, which is on-policy within every
    /// iteration by construction.
    pub policy_lag_mean: Option<f32>,
    /// Worst per-transition staleness seen (async loop only).
    pub policy_lag_max: Option<u32>,
    pub curve: Vec<CurvePoint>,
}

/// `{x:.1}`, or `n/a` for the not-yet-measurable sentinel values a run
/// with no completed episodes produces (NaN mean, -inf best window).
fn fmt_return(x: f32) -> String {
    if x.is_finite() {
        format!("{x:.1}")
    } else {
        "n/a".into()
    }
}

impl TrainSummary {
    /// Human-readable block for the CLI / EXPERIMENTS.md.
    pub fn render(&self) -> String {
        let eval_line = match self.eval_return {
            Some(r) => format!("\neval return       {r:.1} (greedy)"),
            None => String::new(),
        };
        let lag_line = match (self.policy_lag_mean, self.policy_lag_max) {
            (Some(mean), Some(max)) => {
                format!("\npolicy lag        mean {mean:.2} / max {max} updates")
            }
            _ => String::new(),
        };
        format!(
            "== train {} / {} ==\n\
             backend           {} ({})\n\
             envs              {}\n\
             env steps         {}\n\
             iterations        {}\n\
             wall time         {:.1}s  ({:.0} env-steps/s)\n\
             episodes          {}\n\
             final return      {} (best window {})\n\
             policy params     {}{}{}",
            self.env_id,
            self.executor,
            self.backend,
            self.precision,
            self.num_envs,
            self.env_steps,
            self.iterations,
            self.wall_secs,
            self.env_steps as f64 / self.wall_secs.max(1e-9),
            self.episodes,
            fmt_return(self.final_return),
            fmt_return(self.best_return),
            self.param_count,
            lag_line,
            eval_line,
        )
    }

    /// Write the learning curve as CSV (`env_steps,wall_secs,mean_return`).
    /// Missing parent directories are created; I/O errors carry the
    /// offending path. Iterations whose trailing window held no
    /// completed episode yet have no mean to report: their
    /// `mean_return` field is left blank (the row itself stays, so line
    /// count still tracks iterations) instead of emitting a literal
    /// `NaN` that chokes most CSV readers.
    pub fn write_curve_csv(&self, path: &str) -> Result<()> {
        let mut s = String::from("env_steps,wall_secs,mean_return\n");
        for p in &self.curve {
            if p.mean_return.is_finite() {
                s.push_str(&format!("{},{:.3},{:.3}\n", p.env_steps, p.wall_secs, p.mean_return));
            } else {
                s.push_str(&format!("{},{:.3},\n", p.env_steps, p.wall_secs));
            }
        }
        let target = std::path::Path::new(path);
        if let Some(parent) = target.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| {
                    Error::Io(std::io::Error::new(
                        e.kind(),
                        format!("creating curve dir {}: {e}", parent.display()),
                    ))
                })?;
            }
        }
        std::fs::write(target, s).map_err(|e| {
            Error::Io(std::io::Error::new(e.kind(), format!("writing curve {path}: {e}")))
        })?;
        Ok(())
    }
}

/// Executors that only make sense for throughput benchmarking — they
/// cannot drive the trainer's synchronous vectorized contract.
fn benchmark_only(k: ExecutorKind) -> bool {
    matches!(
        k,
        ExecutorKind::EnvPoolAsync
            | ExecutorKind::EnvPoolAsyncVec
            | ExecutorKind::EnvPoolNumaAsync
            | ExecutorKind::EnvPoolNumaAsyncVec
            | ExecutorKind::SampleFactory
            | ExecutorKind::SampleFactoryVec
    )
}

fn reject_benchmark_only(cfg: &TrainConfig) -> Error {
    Error::Config(format!(
        "the PPO trainer drives the synchronous vectorized contract; \
         executor {} is benchmark-only here (see `envpool bench`) — pass \
         --async-train to run the decoupled actor–learner loop on the async pool",
        cfg.executor
    ))
}

/// Load and cross-check the scenario named by `cfg.scenario` (`None`
/// when the config has none): total lane count must match `num_envs`,
/// and the groups must share one spec — the trainer's rollout buffers
/// and policy have a single `[obs_dim]`/action shape, so ragged mixes
/// are a config error here (the pool itself runs them fine; they are
/// for throughput work, not this trainer).
fn load_trainer_scenario(cfg: &TrainConfig) -> Result<Option<crate::config::ScenarioConfig>> {
    let Some(path) = &cfg.scenario else { return Ok(None) };
    let sc = crate::config::ScenarioConfig::load(path)?;
    if sc.num_envs() != cfg.num_envs {
        return Err(Error::Config(format!(
            "scenario {path} declares {} envs but num_envs is {}; set --num-envs {}",
            sc.num_envs(),
            cfg.num_envs,
            sc.num_envs()
        )));
    }
    let union = crate::envs::registry::scenario_spec(&sc)?;
    if union.uniform_group_spec().is_none() {
        let shapes: Vec<String> = union
            .groups
            .iter()
            .map(|g| format!("{}: obs {:?}", g.task_id, g.spec.obs_shape))
            .collect();
        return Err(Error::Config(format!(
            "the trainer needs every scenario group to share one spec (single policy \
             head); {path} mixes {}",
            shapes.join(", ")
        )));
    }
    Ok(Some(sc))
}

fn build_executor(cfg: &TrainConfig) -> Result<Box<dyn VectorEnv>> {
    // Benchmark-only executors first: that rejection is the actionable
    // message (an async pool *does* wrap — it just cannot train).
    if benchmark_only(cfg.executor) {
        return Err(reject_benchmark_only(cfg));
    }
    // The engine-side wrapper stack lives in the pool; the bare baseline
    // executors do not wrap. Reject the combination instead of silently
    // training with different semantics per executor.
    let pool_executor =
        matches!(cfg.executor, ExecutorKind::EnvPoolSync | ExecutorKind::EnvPoolSyncVec);
    if cfg.normalize_obs && !pool_executor {
        return Err(Error::Config(format!(
            "normalize_obs requires an EnvPool executor (engine-side wrapper stack); \
             executor {} does not wrap",
            cfg.executor
        )));
    }
    // Pooled stats exist only in the batch-wise VecWrapper layer, so the
    // executor must select the vectorized pool engine.
    if cfg.normalize_obs_shared && cfg.executor != ExecutorKind::EnvPoolSyncVec {
        return Err(Error::Config(format!(
            "normalize_obs_shared (pooled VecNormalize-style stats) requires the \
             envpool-sync-vec executor (ExecMode::Vectorized); executor {} only has \
             per-lane stats",
            cfg.executor
        )));
    }
    Ok(match cfg.executor {
        ExecutorKind::ForLoop => {
            Box::new(ForLoopExecutor::new(&cfg.env_id, cfg.num_envs, cfg.seed)?)
        }
        ExecutorKind::ForLoopVec => Box::new(VecForLoopExecutor::new_with_lanes(
            &cfg.env_id,
            cfg.num_envs,
            cfg.seed,
            cfg.lane_pass,
        )?),
        ExecutorKind::Subprocess => {
            Box::new(SubprocessExecutor::new(&cfg.env_id, cfg.num_envs, cfg.seed)?)
        }
        ExecutorKind::EnvPoolSync | ExecutorKind::EnvPoolSyncVec => {
            let pool = match load_trainer_scenario(cfg)? {
                // TrainConfig::validate rejects the normalization flags
                // with a scenario, so no pool-level wrapper stack here.
                Some(sc) => EnvPool::make(
                    PoolConfig::new(&cfg.env_id)
                        .scenario(sc)
                        .sync()
                        .num_threads(cfg.num_threads)
                        .seed(cfg.seed)
                        .exec_mode(cfg.executor.pool_exec_mode())
                        .lane_pass(cfg.lane_pass),
                )?,
                None => EnvPool::make(
                    PoolConfig::new(&cfg.env_id)
                        .num_envs(cfg.num_envs)
                        .sync()
                        .num_threads(cfg.num_threads)
                        .seed(cfg.seed)
                        .exec_mode(cfg.executor.pool_exec_mode())
                        .wrappers(cfg.wrap_config())
                        .lane_pass(cfg.lane_pass),
                )?,
            };
            Box::new(PoolVectorEnv::new(pool)?)
        }
        ExecutorKind::EnvPoolAsync
        | ExecutorKind::EnvPoolAsyncVec
        | ExecutorKind::EnvPoolNumaAsync
        | ExecutorKind::EnvPoolNumaAsyncVec
        | ExecutorKind::SampleFactory
        | ExecutorKind::SampleFactoryVec => return Err(reject_benchmark_only(cfg)),
    })
}

/// Train per `cfg`; returns the summary.
pub fn train(cfg: &TrainConfig) -> Result<TrainSummary> {
    let (s, _) = train_profiled(cfg)?;
    Ok(s)
}

/// Reusable minibatch gather buffers for [`train_one_minibatch`].
pub(super) struct MbScratch {
    obs: Vec<f32>,
    act: Vec<f32>,
    logp: Vec<f32>,
    adv: Vec<f32>,
    ret: Vec<f32>,
}

impl MbScratch {
    pub(super) fn new() -> Self {
        MbScratch {
            obs: Vec::new(),
            act: Vec::new(),
            logp: Vec::new(),
            adv: Vec::new(),
            ret: Vec::new(),
        }
    }
}

/// Mean of the trailing `window` episode returns (NaN when none have
/// completed), summing newest-first — the iteration order the old
/// per-iteration `collect` used, kept so rerun curves stay
/// bit-identical. Allocation-free: both training loops call this once
/// per learner iteration and used to clone the tail into a fresh `Vec`
/// each time.
pub(super) fn trailing_mean(completed: &[f32], window: usize) -> f32 {
    let n = completed.len().min(window);
    if n == 0 {
        return f32::NAN;
    }
    completed[completed.len() - n..].iter().rev().sum::<f32>() / n as f32
}

/// GAE over a finished rollout with CleanRL's done|truncated merge —
/// the advantage path shared by the synchronous loop below and the
/// decoupled async loop (`super::async_ppo`).
pub(super) fn compute_gae(
    backend: &mut dyn ComputeBackend,
    buf: &RolloutBuffer,
    last_values: &[f32],
    prof: &mut TimeBreakdown,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let merged: Vec<f32> = buf
        .dones
        .iter()
        .zip(&buf.truncs)
        .map(|(&d, &tr)| if d != 0.0 || tr != 0.0 { 1.0 } else { 0.0 })
        .collect();
    let zeros = vec![0.0f32; buf.rows()];
    prof.time(Category::Training, || {
        backend.gae(&buf.rewards, &buf.values, last_values, &merged, &zeros)
    })
}

/// One PPO minibatch update — gather rows `sl`, step the backend, check
/// divergence. The single update step both training loops drive; `iter`
/// only labels the divergence error.
pub(super) fn train_one_minibatch(
    backend: &mut dyn ComputeBackend,
    buf: &RolloutBuffer,
    adv: &[f32],
    ret: &[f32],
    sl: &[usize],
    lr: f32,
    prof: &mut TimeBreakdown,
    scratch: &mut MbScratch,
    iter: usize,
) -> Result<()> {
    prof.time(Category::Other, || {
        buf.gather(
            sl,
            adv,
            ret,
            &mut scratch.obs,
            &mut scratch.act,
            &mut scratch.logp,
            &mut scratch.adv,
            &mut scratch.ret,
        );
    });
    let mb = Minibatch {
        obs: &scratch.obs,
        actions: &scratch.act,
        logp: &scratch.logp,
        adv: &scratch.adv,
        ret: &scratch.ret,
    };
    let stats = prof.time(Category::Training, || backend.train_minibatch(&mb, lr))?;
    if !stats.loss.is_finite() {
        return Err(Error::Config(format!(
            "loss diverged at iteration {iter} (loss={})",
            stats.loss
        )));
    }
    Ok(())
}

/// Train per `cfg`, also returning the Figure-4 time breakdown.
pub fn train_profiled(cfg: &TrainConfig) -> Result<(TrainSummary, TimeBreakdown)> {
    // The decoupled actor–learner loop has its own driver: it *requires*
    // an async executor, so dispatch before the benchmark-only check.
    if cfg.async_train {
        return super::async_ppo::train_async_profiled(cfg);
    }
    // Reject benchmark-only executors up front (before any artifact /
    // runtime loading) so configuration errors surface first; if this
    // guard ever misses a kind, `build_executor` still returns the same
    // error, just later.
    if benchmark_only(cfg.executor) {
        return Err(reject_benchmark_only(cfg));
    }
    // Library callers can hand-build a TrainConfig, so the shape
    // invariants (non-zero num_steps/num_minibatches, batch bounds, ...)
    // must be enforced here too, not only on the CLI path.
    cfg.validate()?;
    // A scenario's groups must share one spec to train (checked with an
    // actionable error in `load_trainer_scenario`); the backend then
    // sees that uniform per-group spec — identical shapes to the
    // pool's union, since a uniform mix pads nothing.
    let env_spec = match load_trainer_scenario(cfg)? {
        Some(sc) => {
            let union = crate::envs::registry::scenario_spec(&sc)?;
            union.uniform_group_spec().expect("load_trainer_scenario checked").clone()
        }
        None => crate::envs::registry::spec_for_wrapped(&cfg.env_id, &cfg.wrap_config())?,
    };
    let mut backend: Box<dyn ComputeBackend> = make_backend(cfg, &env_spec)?;
    let bs = backend.spec().clone();
    let t_len = bs.num_steps;
    let n = bs.num_envs;

    let mut ex = build_executor(cfg)?;
    let mut prof = TimeBreakdown::new();
    let mut rng = Pcg32::new(cfg.seed ^ 0x70706f, 999);

    let steps_per_iter = (t_len * n) as u64;
    // Round the step budget *up* to whole rollouts: `--total-steps 1000`
    // with 8 envs × 64 steps used to silently truncate to 512 trained
    // steps. The summary's `env_steps` reports what actually trained.
    let iterations = cfg.total_steps.div_ceil(steps_per_iter).max(1) as usize;
    let minibatch = bs.minibatch_size;
    let n_minibatches = bs.num_minibatches;
    let epochs = cfg.update_epochs;

    let act_cols = if bs.continuous { bs.act_dim } else { 1 };
    let mut buf = RolloutBuffer::new(t_len, n, bs.obs_dim, act_cols);
    let mut out = ex.make_output();
    ex.reset(&mut out)?;
    let mut obs = out.obs.clone();

    // episodic return tracking
    let mut ep_ret = vec![0.0f32; n];
    let mut completed: Vec<f32> = Vec::new();
    let window = 20usize;

    // minibatch gather scratch
    let mut scratch = MbScratch::new();

    let start = Instant::now();
    let mut curve = Vec::new();
    let mut best = f32::NEG_INFINITY;

    for iter in 0..iterations {
        // ---- rollout ----
        for t in 0..t_len {
            let pol = prof.time(Category::Inference, || backend.forward(&obs))?;
            let (actions, logp) = if bs.continuous {
                sampler::gaussian(&pol.dist, &pol.log_std, n, bs.act_dim, &mut rng)
            } else {
                sampler::categorical(&pol.dist, n, bs.act_dim, &mut rng)
            };
            prof.time(Category::EnvStep, || ex.step(&actions, &mut out))?;
            prof.time(Category::Other, || {
                buf.store(t, &obs, &actions, &logp, &pol.value, &out.rew, &out.done, &out.trunc);
                for i in 0..n {
                    ep_ret[i] += out.rew[i];
                    if out.finished(i) {
                        completed.push(ep_ret[i]);
                        ep_ret[i] = 0.0;
                    }
                }
                obs.copy_from_slice(&out.obs);
            });
        }

        // ---- advantages (backend GAE: AOT kernel or native scan) ----
        let last_pol = prof.time(Category::Inference, || backend.forward(&obs))?;
        let (adv, ret) = compute_gae(&mut *backend, &buf, &last_pol.value, &mut prof)?;

        // ---- updates ----
        let lr = if cfg.anneal_lr {
            cfg.learning_rate * (1.0 - iter as f32 / iterations as f32)
        } else {
            cfg.learning_rate
        };
        for _epoch in 0..epochs {
            let idx = buf.shuffled_indices(&mut rng);
            for k in 0..n_minibatches {
                let sl = &idx[k * minibatch..(k + 1) * minibatch];
                train_one_minibatch(
                    &mut *backend,
                    &buf,
                    &adv,
                    &ret,
                    sl,
                    lr,
                    &mut prof,
                    &mut scratch,
                    iter,
                )?;
            }
        }
        prof.bump_iteration();

        // ---- bookkeeping ----
        let mean_ret = trailing_mean(&completed, window);
        if mean_ret.is_finite() {
            best = best.max(mean_ret);
        }
        curve.push(CurvePoint {
            env_steps: steps_per_iter * (iter as u64 + 1),
            wall_secs: start.elapsed().as_secs_f64(),
            mean_return: mean_ret,
        });
        // Optional early stop once the trailing window hits the target
        // (lr annealing still follows the planned schedule).
        if let Some(target) = cfg.target_return {
            if mean_ret.is_finite() && mean_ret >= target {
                break;
            }
        }
    }

    let wall = start.elapsed().as_secs_f64();
    let final_ret = curve.last().map(|p| p.mean_return).unwrap_or(f32::NAN);
    let ran = curve.len();
    // Optional greedy evaluation on the trained backend (works on both
    // compute tiers — `coordinator::eval` is backend-generic).
    let eval_return = if cfg.eval_episodes > 0 {
        Some(super::eval::evaluate(
            &mut *backend,
            &cfg.env_id,
            cfg.eval_episodes,
            cfg.seed ^ 0x5eed,
        )?)
    } else {
        None
    };
    let summary = TrainSummary {
        env_id: cfg.env_id.clone(),
        executor: cfg.executor,
        backend: backend.kind().to_string(),
        precision: backend.precision().to_string(),
        eval_return,
        num_envs: n,
        env_steps: steps_per_iter * ran as u64,
        iterations: ran,
        wall_secs: wall,
        episodes: completed.len(),
        final_return: final_ret,
        best_return: best,
        param_count: backend.param_count(),
        policy_lag_mean: None,
        policy_lag_max: None,
        curve,
    };
    Ok((summary, prof))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;

    /// Native-backend smoke config: runs in every checkout (no PJRT, no
    /// artifacts), with a short rollout so tests stay fast.
    fn smoke_cfg(env: &str, n: usize, steps: u64) -> TrainConfig {
        TrainConfig {
            env_id: env.into(),
            executor: ExecutorKind::EnvPoolSync,
            backend: BackendKind::Native,
            num_envs: n,
            batch_size: n,
            num_threads: 2,
            num_steps: 64,
            total_steps: steps,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn smoke_train_cartpole_two_iterations() {
        let cfg = smoke_cfg("CartPole-v1", 8, 2 * 8 * 64);
        let (s, prof) = train_profiled(&cfg).unwrap();
        assert_eq!(s.backend, "native");
        assert_eq!(s.iterations, 2);
        assert_eq!(s.env_steps, 1024);
        assert!(s.episodes > 0, "random-ish cartpole episodes must finish");
        assert!(s.final_return.is_finite());
        assert!(prof.total(Category::EnvStep).as_nanos() > 0);
        assert!(prof.total(Category::Training).as_nanos() > 0);
        assert!(prof.total(Category::Inference).as_nanos() > 0);
    }

    #[test]
    fn smoke_train_continuous_pendulum() {
        let cfg = smoke_cfg("Pendulum-v1", 4, 4 * 64);
        let (s, _) = train_profiled(&cfg).unwrap();
        assert_eq!(s.iterations, 1);
        assert!(s.env_steps == 256);
    }

    #[test]
    fn async_executor_rejected_for_training() {
        // Benchmark-only executors must be rejected with a configuration
        // error *before* any backend/artifact loading.
        for kind in [
            ExecutorKind::EnvPoolAsync,
            ExecutorKind::EnvPoolAsyncVec,
            ExecutorKind::SampleFactory,
            ExecutorKind::SampleFactoryVec,
        ] {
            let mut cfg = smoke_cfg("CartPole-v1", 8, 1024);
            cfg.executor = kind;
            match train(&cfg) {
                Err(Error::Config(msg)) => assert!(msg.contains("benchmark-only"), "{msg}"),
                other => panic!("{kind}: expected Config error, got {other:?}"),
            }
        }
    }

    #[test]
    fn forloop_matches_envpool_learning_signal() {
        // Same seed => identical rollouts => identical curve between
        // executors (the "pure speedup without cost" property end to end).
        let mut a = smoke_cfg("CartPole-v1", 8, 1024);
        a.executor = ExecutorKind::ForLoop;
        let mut b = smoke_cfg("CartPole-v1", 8, 1024);
        b.executor = ExecutorKind::EnvPoolSync;
        let (sa, _) = train_profiled(&a).unwrap();
        let (sb, _) = train_profiled(&b).unwrap();
        assert_eq!(sa.episodes, sb.episodes);
        assert_eq!(sa.final_return, sb.final_return);
    }

    #[test]
    fn shared_normalization_requires_vectorized_pool() {
        let mut cfg = smoke_cfg("CartPole-v1", 8, 1024);
        cfg.normalize_obs_shared = true;
        // scalar pool engine: rejected with an actionable message
        match train(&cfg) {
            Err(Error::Config(msg)) => assert!(msg.contains("envpool-sync-vec"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        // vectorized pool engine: trains
        cfg.executor = ExecutorKind::EnvPoolSyncVec;
        let s = train(&cfg).unwrap();
        assert!(s.env_steps > 0);
        // bare baseline executors: rejected too
        cfg.executor = ExecutorKind::ForLoopVec;
        assert!(matches!(train(&cfg), Err(Error::Config(_))));
    }

    #[test]
    fn invalid_shapes_error_on_the_library_path_too() {
        // validate() runs inside train_profiled, not just apply_args:
        // a hand-built config must get a Config error, not a panic.
        let mut cfg = smoke_cfg("CartPole-v1", 8, 1024);
        cfg.num_steps = 0;
        assert!(matches!(train(&cfg), Err(Error::Config(_))));
        let mut cfg = smoke_cfg("CartPole-v1", 8, 1024);
        cfg.num_minibatches = 0;
        assert!(matches!(train(&cfg), Err(Error::Config(_))));
    }

    #[test]
    fn target_return_stops_early() {
        // A target below the random-policy return stops after the first
        // iteration that completes an episode window.
        let mut cfg = smoke_cfg("CartPole-v1", 8, 50 * 8 * 64);
        cfg.target_return = Some(1.0); // any completed episode beats this
        let s = train(&cfg).unwrap();
        assert!(s.iterations < 50, "target_return must stop early, ran {}", s.iterations);
        assert_eq!(s.env_steps, (s.iterations * 8 * 64) as u64);
        assert_eq!(s.curve.len(), s.iterations);
    }

    #[test]
    fn total_steps_round_up_instead_of_silently_truncating() {
        // Regression: 1000 steps over 512-step iterations (8 envs × 64)
        // used to truncate to ONE iteration = 512 trained steps. The
        // budget now rounds up to whole rollouts and the summary reports
        // the steps actually trained.
        let cfg = smoke_cfg("CartPole-v1", 8, 1000);
        let (s, _) = train_profiled(&cfg).unwrap();
        assert_eq!(s.iterations, 2, "1000 steps must round up to 2×512");
        assert_eq!(s.env_steps, 1024);
    }

    #[test]
    fn unfilled_return_window_blanks_csv_and_renders_na() {
        // Regression: iterations before any completed episode used to
        // emit literal `NaN` rows into the curve CSV and `best window
        // -inf` into the rendered block.
        let cfg = smoke_cfg("CartPole-v1", 4, 4 * 64);
        let (mut s, _) = train_profiled(&cfg).unwrap();
        s.curve.insert(
            0,
            CurvePoint { env_steps: 1, wall_secs: 0.1, mean_return: f32::NAN },
        );
        s.final_return = f32::NAN;
        s.best_return = f32::NEG_INFINITY;
        let r = s.render();
        assert!(r.contains("n/a (best window n/a)"), "{r}");
        assert!(!r.contains("NaN") && !r.contains("inf"), "{r}");
        let path = std::env::temp_dir()
            .join(format!("envpool-nan-curve-{}.csv", std::process::id()));
        s.write_curve_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        // row count invariant holds, but the NaN row's field is blank
        assert_eq!(text.lines().count(), 1 + s.curve.len());
        let nan_row = text.lines().nth(1).unwrap();
        assert!(nan_row.ends_with(','), "blank field expected: {nan_row}");
        assert!(!text.contains("NaN"), "{text}");
    }

    #[test]
    fn sync_summary_reports_no_policy_lag() {
        // The synchronous loop is on-policy within each iteration: lag
        // fields stay None and the render omits the line entirely.
        let cfg = smoke_cfg("CartPole-v1", 4, 4 * 64);
        let (s, _) = train_profiled(&cfg).unwrap();
        assert_eq!((s.policy_lag_mean, s.policy_lag_max), (None, None));
        assert!(!s.render().contains("policy lag"));
    }

    #[test]
    fn curve_csv_creates_parents_and_reports_path_on_error() {
        let cfg = smoke_cfg("CartPole-v1", 4, 4 * 64);
        let s = train(&cfg).unwrap();
        let dir = std::env::temp_dir().join(format!("envpool-curve-{}", std::process::id()));
        let nested = dir.join("a/b/curve.csv");
        s.write_curve_csv(nested.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&nested).unwrap();
        assert!(text.starts_with("env_steps,wall_secs,mean_return"));
        assert_eq!(text.lines().count(), 1 + s.curve.len());
        // error path: the parent "directory" is a file → the error must
        // name the offending path instead of a bare io message
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, "x").unwrap();
        let bad = blocker.join("curve.csv");
        let err = s.write_curve_csv(bad.to_str().unwrap()).unwrap_err();
        assert!(
            err.to_string().contains("blocker"),
            "error must carry the path: {err}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
