//! Dependency-free portable SIMD: fixed-width lane types over plain
//! arrays, written so the auto-vectorizer turns every elementwise op
//! into vector instructions, plus optional `core::arch` x86_64
//! intrinsics behind **runtime** feature detection for the one hot
//! reduction ([`dot_f32`]).
//!
//! # Why hand-rolled
//!
//! The vendored crate set has no `wide`/`packed_simd`, and
//! `std::simd` is nightly-only. A `#[repr(transparent)]` wrapper over
//! `[f32; N]` with `#[inline]` per-lane loops compiles to the same
//! vector code on every stable toolchain: LLVM reliably vectorizes
//! straight-line loops of known trip count over contiguous arrays.
//!
//! # The parity contract (what SIMD is allowed to change)
//!
//! Every elementwise op here (`+ - * /`, `min/max/clamp/abs`, compares,
//! `select`, the [`math`] kernels) applies the **same scalar operation
//! per lane in the same order** as the corresponding scalar code, so a
//! lane pass built from them is **bitwise identical** to the scalar
//! reference loop — `tests/simd_parity.rs` asserts 0 ULP for the
//! classic-control kernels at every lane width. (The walker family's
//! lane-grouped *solver* additionally swaps libm trig for the [`math`]
//! twins at widths > 1, and therefore ships under a documented
//! tolerance budget instead — see `envs::mujoco::batch` and
//! `tests/mujoco_batch_parity.rs`.) The only ops that reassociate — and
//! therefore carry an explicit ULP budget instead of bitwise equality —
//! are the horizontal reductions: [`dot_f32`] accumulates in `LANES`
//! partial sums, and [`gemm_bt_f32`] computes every output element as
//! one such dot, so the whole GEMM inherits the same per-element
//! `γ_n`-style bound (asserted vs the sequential axpy GEMV in
//! `tests/simd_parity.rs`). Nothing else is allowed to reassociate; in
//! particular there is no FMA contraction anywhere (Rust never
//! contracts without `mul_add`, and this module never calls it).
//!
//! # Lane-width selection
//!
//! [`LanePass`] is the kernel config every SIMD consumer takes:
//! `scalar` (width 1 — the reference loop), forced widths 4/8 (the
//! parity suite and the `simd-parity` CI job pin all three), or `auto`
//! (runtime detection: 8 when AVX2 is present, 4 otherwise, overridable
//! via `ENVPOOL_LANE_WIDTH`). For the bitwise kernels the choice is
//! purely a throughput knob — determinism tests stay valid across
//! widths, machines, and `ExecMode`s; for the walker solver widths > 1
//! trade bitwise equality for the documented tolerance budget.

pub mod math;
#[cfg(target_arch = "x86_64")]
mod x86;

use crate::{Error, Result};

/// Portable f32 lane group (`N` lanes processed per "instruction").
#[derive(Debug, Clone, Copy, PartialEq)]
#[repr(transparent)]
pub struct F32s<const N: usize>(pub [f32; N]);

/// Portable f64 lane group.
#[derive(Debug, Clone, Copy, PartialEq)]
#[repr(transparent)]
pub struct F64s<const N: usize>(pub [f64; N]);

/// 8 × f32 — one AVX register.
pub type F32x8 = F32s<8>;
/// 4 × f32 — one SSE/NEON register.
pub type F32x4 = F32s<4>;
/// 4 × f64 — one AVX register.
pub type F64x4 = F64s<4>;

/// Per-lane boolean mask produced by the compare ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mask<const N: usize>(pub [bool; N]);

macro_rules! lane_type {
    ($name:ident, $elem:ty) => {
        impl<const N: usize> $name<N> {
            /// All lanes set to `x`.
            #[inline(always)]
            pub fn splat(x: $elem) -> Self {
                $name([x; N])
            }

            /// Load `N` lanes from the front of `src` (panics if short).
            #[inline(always)]
            pub fn load(src: &[$elem]) -> Self {
                let mut out = [0.0; N];
                out.copy_from_slice(&src[..N]);
                $name(out)
            }

            /// Load `min(N, src.len())` lanes, padding the rest with
            /// `fill` — the masked-tail load (padded lanes are computed
            /// and then discarded by the caller's masked store).
            #[inline(always)]
            pub fn load_or(src: &[$elem], fill: $elem) -> Self {
                let mut out = [fill; N];
                let n = src.len().min(N);
                out[..n].copy_from_slice(&src[..n]);
                $name(out)
            }

            /// Build lanes from a function of the lane index.
            #[inline(always)]
            pub fn from_fn(f: impl FnMut(usize) -> $elem) -> Self {
                $name(std::array::from_fn(f))
            }

            /// Store all `N` lanes to the front of `dst`.
            #[inline(always)]
            pub fn store(self, dst: &mut [$elem]) {
                dst[..N].copy_from_slice(&self.0);
            }

            /// Per-lane minimum (`<$elem>::min` semantics, same as the
            /// scalar code).
            #[inline(always)]
            pub fn min(self, o: Self) -> Self {
                Self::from_fn(|i| self.0[i].min(o.0[i]))
            }

            /// Per-lane maximum.
            #[inline(always)]
            pub fn max(self, o: Self) -> Self {
                Self::from_fn(|i| self.0[i].max(o.0[i]))
            }

            /// Per-lane `<$elem>::clamp` (identical NaN semantics to the
            /// scalar `.clamp(lo, hi)` calls it replaces).
            #[inline(always)]
            pub fn clamp(self, lo: $elem, hi: $elem) -> Self {
                Self::from_fn(|i| self.0[i].clamp(lo, hi))
            }

            /// Per-lane absolute value.
            #[inline(always)]
            pub fn abs(self) -> Self {
                Self::from_fn(|i| self.0[i].abs())
            }

            /// Per-lane `signum` (`<$elem>::signum` semantics: ±1.0
            /// carrying the lane's sign, NaN for NaN — identical to the
            /// scalar `.signum()` calls it replaces, so lane passes
            /// built from it stay bitwise).
            #[inline(always)]
            pub fn signum(self) -> Self {
                Self::from_fn(|i| self.0[i].signum())
            }

            /// Per-lane square root (IEEE-exact, so bitwise identical to
            /// the scalar `.sqrt()` calls it replaces).
            #[inline(always)]
            pub fn sqrt(self) -> Self {
                Self::from_fn(|i| self.0[i].sqrt())
            }

            /// Lane-wise `self > o`.
            #[inline(always)]
            pub fn gt(self, o: Self) -> Mask<N> {
                Mask(std::array::from_fn(|i| self.0[i] > o.0[i]))
            }

            /// Lane-wise `self < o`.
            #[inline(always)]
            pub fn lt(self, o: Self) -> Mask<N> {
                Mask(std::array::from_fn(|i| self.0[i] < o.0[i]))
            }

            /// Lane-wise `self >= o`.
            #[inline(always)]
            pub fn ge(self, o: Self) -> Mask<N> {
                Mask(std::array::from_fn(|i| self.0[i] >= o.0[i]))
            }

            /// Lane-wise `self <= o`.
            #[inline(always)]
            pub fn le(self, o: Self) -> Mask<N> {
                Mask(std::array::from_fn(|i| self.0[i] <= o.0[i]))
            }
        }

        impl<const N: usize> std::ops::Add for $name<N> {
            type Output = Self;
            #[inline(always)]
            fn add(self, o: Self) -> Self {
                Self::from_fn(|i| self.0[i] + o.0[i])
            }
        }

        impl<const N: usize> std::ops::Sub for $name<N> {
            type Output = Self;
            #[inline(always)]
            fn sub(self, o: Self) -> Self {
                Self::from_fn(|i| self.0[i] - o.0[i])
            }
        }

        impl<const N: usize> std::ops::Mul for $name<N> {
            type Output = Self;
            #[inline(always)]
            fn mul(self, o: Self) -> Self {
                Self::from_fn(|i| self.0[i] * o.0[i])
            }
        }

        impl<const N: usize> std::ops::Div for $name<N> {
            type Output = Self;
            #[inline(always)]
            fn div(self, o: Self) -> Self {
                Self::from_fn(|i| self.0[i] / o.0[i])
            }
        }

        impl<const N: usize> std::ops::Neg for $name<N> {
            type Output = Self;
            #[inline(always)]
            fn neg(self) -> Self {
                Self::from_fn(|i| -self.0[i])
            }
        }

    };
}

lane_type!(F32s, f32);
lane_type!(F64s, f64);

impl<const N: usize> Mask<N> {
    /// Per-lane select into f32 lanes: `t` where the mask lane is set,
    /// else `f`.
    #[inline(always)]
    pub fn select_f32(self, t: F32s<N>, f: F32s<N>) -> F32s<N> {
        F32s::from_fn(|i| if self.0[i] { t.0[i] } else { f.0[i] })
    }

    /// Per-lane select into f64 lanes.
    #[inline(always)]
    pub fn select_f64(self, t: F64s<N>, f: F64s<N>) -> F64s<N> {
        F64s::from_fn(|i| if self.0[i] { t.0[i] } else { f.0[i] })
    }
}

impl<const N: usize> Mask<N> {
    /// Build a mask from a function of the lane index (used by the
    /// masked lane-group passes to fold step/tail conditions in).
    #[inline(always)]
    pub fn from_fn(f: impl FnMut(usize) -> bool) -> Self {
        Mask(std::array::from_fn(f))
    }

    /// Any lane set?
    #[inline(always)]
    pub fn any(self) -> bool {
        self.0.iter().any(|&b| b)
    }

    /// All lanes set?
    #[inline(always)]
    pub fn all(self) -> bool {
        self.0.iter().all(|&b| b)
    }
}

impl<const N: usize> std::ops::BitOr for Mask<N> {
    type Output = Self;
    /// Lane-wise OR.
    #[inline(always)]
    fn bitor(self, o: Self) -> Self {
        Mask(std::array::from_fn(|i| self.0[i] | o.0[i]))
    }
}

impl<const N: usize> std::ops::BitAnd for Mask<N> {
    type Output = Self;
    /// Lane-wise AND.
    #[inline(always)]
    fn bitand(self, o: Self) -> Self {
        Mask(std::array::from_fn(|i| self.0[i] & o.0[i]))
    }
}

impl<const N: usize> std::ops::Not for Mask<N> {
    type Output = Self;
    /// Lane-wise NOT.
    #[inline(always)]
    fn not(self) -> Self {
        Mask(std::array::from_fn(|i| !self.0[i]))
    }
}

impl<const N: usize> F32s<N> {
    /// Per-lane `(sin, cos)` via the shared deterministic kernel
    /// ([`math::sin_cos_f32`]): bitwise identical to the scalar twin,
    /// branchless per lane so the loop vectorizes.
    #[inline(always)]
    pub fn sin_cos(self) -> (Self, Self) {
        let mut s = [0.0f32; N];
        let mut c = [0.0f32; N];
        for i in 0..N {
            let (si, ci) = math::sin_cos_f32(self.0[i]);
            s[i] = si;
            c[i] = ci;
        }
        (F32s(s), F32s(c))
    }

    /// Per-lane sine (shared kernel, see [`Self::sin_cos`]).
    #[inline(always)]
    pub fn sin(self) -> Self {
        Self::from_fn(|i| math::sin_f32(self.0[i]))
    }

    /// Per-lane cosine (shared kernel, see [`Self::sin_cos`]).
    #[inline(always)]
    pub fn cos(self) -> Self {
        Self::from_fn(|i| math::cos_f32(self.0[i]))
    }

    /// Per-lane `tanh` via the shared deterministic kernel
    /// ([`math::tanh_f32`]): bitwise identical to the scalar twin,
    /// branchless per lane so the loop vectorizes. Carries the twin's
    /// documented ≤ 2 ULP budget vs demoted f64 libm — the f32
    /// inference path's activation (the f64 training path keeps libm).
    #[inline(always)]
    pub fn tanh(self) -> Self {
        Self::from_fn(|i| math::tanh_f32(self.0[i]))
    }
}

// ---------------------------------------------------------------------
// Runtime capability detection
// ---------------------------------------------------------------------

/// CPU SIMD capabilities detected at runtime.
#[derive(Debug, Clone, Copy, Default)]
pub struct Caps {
    /// AVX2 available (x86_64 only; always false elsewhere).
    pub avx2: bool,
}

/// Detect CPU capabilities. Cached in a `OnceLock` because [`dot_f32`]
/// consults this on the f32 backward hot path (once per hidden unit per
/// sample) — after the first call this is a single atomic load.
#[inline]
pub fn caps() -> Caps {
    static CAPS: std::sync::OnceLock<Caps> = std::sync::OnceLock::new();
    *CAPS.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            Caps { avx2: std::arch::is_x86_feature_detected!("avx2") }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            Caps::default()
        }
    })
}

// ---------------------------------------------------------------------
// Lane-width configuration
// ---------------------------------------------------------------------

/// Which lane pass a SIMD-capable kernel runs — the "kernel config"
/// knob wired through `PoolConfig::lane_pass`, `TrainConfig::lane_pass`
/// and `--lane-width {1,4,8,auto}`.
///
/// Width 1 **is** the scalar reference implementation (the pre-SIMD
/// loop, kept verbatim); 4 and 8 are forced lane widths for the parity
/// suite and the `simd-parity` CI job; `Auto` resolves by runtime
/// feature detection, overridable with the `ENVPOOL_LANE_WIDTH`
/// environment variable (values `1|4|8`). For the classic-control
/// kernels all widths are bitwise identical; the walker family's
/// lane-grouped solver is bitwise at width 1 and tolerance-budgeted at
/// 4/8 — see the module docs and `envs::mujoco::batch`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LanePass {
    /// Width 1: the scalar reference loop.
    Scalar,
    /// Forced 4-wide lane groups.
    Width4,
    /// Forced 8-wide lane groups.
    Width8,
    /// Runtime detection (8 with AVX2, else 4), `ENVPOOL_LANE_WIDTH`
    /// override.
    #[default]
    Auto,
}

impl LanePass {
    /// Resolve to a concrete lane width (1, 4 or 8). `Auto` consults
    /// `ENVPOOL_LANE_WIDTH` then [`caps`]; kernels resolve once, in
    /// `VecEnv::set_lane_pass`, so the env lookup is never on the hot
    /// path (and a malformed override panics there, loudly, rather
    /// than silently running the wrong width).
    pub fn width(self) -> usize {
        match self {
            LanePass::Scalar => 1,
            LanePass::Width4 => 4,
            LanePass::Width8 => 8,
            LanePass::Auto => {
                if let Ok(v) = std::env::var("ENVPOOL_LANE_WIDTH") {
                    // An explicit operator override must not fail
                    // silently: a typo here would make every leg of the
                    // CI width matrix run the same width and pass the
                    // per-width parity guarantee vacuously. Same loud
                    // behavior as a bad `--lane-width` CLI value.
                    match v.trim() {
                        "1" | "scalar" => return 1,
                        "4" => return 4,
                        "8" => return 8,
                        "" => {} // unset-equivalent: fall through
                        other => panic!(
                            "ENVPOOL_LANE_WIDTH={other:?}: expected 1|4|8 \
                             (unset it to use runtime detection)"
                        ),
                    }
                }
                if caps().avx2 {
                    8
                } else {
                    4
                }
            }
        }
    }
}

impl std::str::FromStr for LanePass {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "1" | "scalar" => LanePass::Scalar,
            "4" => LanePass::Width4,
            "8" => LanePass::Width8,
            "auto" => LanePass::Auto,
            other => {
                return Err(Error::Config(format!(
                    "unknown lane width {other:?} (expected 1|4|8|auto)"
                )))
            }
        })
    }
}

impl std::fmt::Display for LanePass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            LanePass::Scalar => "1",
            LanePass::Width4 => "4",
            LanePass::Width8 => "8",
            LanePass::Auto => "auto",
        })
    }
}

// ---------------------------------------------------------------------
// Reductions (the reassociating ops — ULP-budgeted, never bitwise)
// ---------------------------------------------------------------------

/// Scalar reference dot product: strictly sequential accumulation —
/// the baseline the ULP budget in `tests/simd_parity.rs` is measured
/// against.
#[inline]
pub fn dot_ref_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// SIMD dot product: 8 partial sums accumulated lane-wise, then a
/// fixed-order horizontal sum, then the scalar tail. **Reassociates**
/// relative to [`dot_ref_f32`]; both satisfy the standard forward error
/// bound `|fl(x·y) − x·y| ≤ γ_n Σ|x_i y_i|`, which the parity suite
/// asserts as an explicit ULP budget. The AVX2 path (runtime-detected)
/// uses the identical accumulation structure, so portable and intrinsic
/// results are bitwise equal — machine choice never changes numerics.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if a.len() >= 16 && caps().avx2 {
        // SAFETY: AVX2 presence was just checked at runtime.
        return unsafe { x86::dot_f32_avx2(a, b) };
    }
    dot_f32_portable(a, b)
}

/// Portable body of [`dot_f32`] (also the reference the AVX2 path must
/// match bitwise).
#[inline]
pub fn dot_f32_portable(a: &[f32], b: &[f32]) -> f32 {
    const L: usize = 8;
    let n = a.len();
    let chunks = n / L;
    let mut acc = F32s::<L>::splat(0.0);
    for c in 0..chunks {
        let va = F32s::<L>::load(&a[c * L..]);
        let vb = F32s::<L>::load(&b[c * L..]);
        acc = acc + va * vb;
    }
    // Fixed-order horizontal sum (lane 0..7), then the sequential tail:
    // the exact structure the AVX2 path reproduces.
    let mut sum = 0.0f32;
    for v in acc.0 {
        sum += v;
    }
    for (&x, &y) in a[chunks * L..n].iter().zip(&b[chunks * L..n]) {
        sum += x * y;
    }
    sum
}

/// ULP distance between two f32 values: 0 means bitwise equal (with
/// `+0.0`/`-0.0` identified), 1 means adjacent representable floats.
/// Maps bit patterns onto a monotone integer line so the distance is
/// well defined across the sign boundary. This is the unit every parity
/// budget in `tests/simd_parity.rs` is expressed in.
pub fn ulp_dist_f32(a: f32, b: f32) -> u64 {
    fn key(x: f32) -> i64 {
        let b = x.to_bits();
        if b & 0x8000_0000 != 0 {
            -((b & 0x7FFF_FFFF) as i64)
        } else {
            b as i64
        }
    }
    (key(a) - key(b)).unsigned_abs()
}

/// `y[i] += a * x[i]` over a lane pass: elementwise (every `y[i]` sees
/// the same single operation the scalar loop applies), so this is
/// **bitwise identical** to the scalar axpy — no reassociation.
#[inline]
pub fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    const L: usize = 8;
    let n = x.len();
    let chunks = n / L;
    let va = F32s::<L>::splat(a);
    for c in 0..chunks {
        let base = c * L;
        let vy = F32s::<L>::load(&y[base..]) + va * F32s::<L>::load(&x[base..]);
        vy.store(&mut y[base..]);
    }
    for (yi, &xi) in y[chunks * L..n].iter_mut().zip(&x[chunks * L..n]) {
        *yi += a * xi;
    }
}

/// Output-dimension tile for [`gemm_bt_f32`]: `64 · d_in` weight floats
/// stay L1-resident (16 KiB at the largest hidden width this crate
/// uses) while every batch row streams against them.
const GEMM_TILE_OUT: usize = 64;

/// Blocked GEMM with **transposed weights**:
/// `out[i·d_out + o] = bias[o] + Σ_k x[i·d_in + k] · wt[o·d_in + k]`
/// for `i < bsz`, `o < d_out`.
///
/// `wt` is `[d_out, d_in]` row-major — the transpose of the `[d_in,
/// d_out]` layout the axpy GEMV walks — so the inner contraction is one
/// contiguous [`dot_f32`] per output element instead of `d_in` strided
/// axpy passes over the whole output row. Blocking runs all batch rows
/// against a 64-row weight tile before moving on, so each weight float
/// is loaded from memory once per `bsz` uses.
///
/// Numerics: each element is `bias[o] + dot_f32(...)` — the dot
/// **reassociates** relative to the sequential GEMV accumulation, with
/// the standard forward bound `≤ γ_{d_in} Σ_k |x_k · w_ko|` per element
/// (`γ_n ≈ n·ε`). `tests/simd_parity.rs` pins this budget against the
/// axpy reference. The result is independent of `bsz`, tile size, and
/// machine (the AVX2 dot is bitwise-equal to the portable one), so
/// determinism across thread counts and batch shapes is preserved.
pub fn gemm_bt_f32(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    out: &mut [f32],
    bsz: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert!(x.len() >= bsz * d_in);
    debug_assert!(wt.len() >= d_out * d_in);
    debug_assert!(bias.len() >= d_out);
    debug_assert!(out.len() >= bsz * d_out);
    let mut o0 = 0;
    while o0 < d_out {
        let o1 = (o0 + GEMM_TILE_OUT).min(d_out);
        for i in 0..bsz {
            let xrow = &x[i * d_in..(i + 1) * d_in];
            let orow = &mut out[i * d_out..(i + 1) * d_out];
            for o in o0..o1 {
                orow[o] = bias[o] + dot_f32(xrow, &wt[o * d_in..(o + 1) * d_in]);
            }
        }
        o0 = o1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_ops_match_scalar_ops_bitwise() {
        let a = F32s::<8>::from_fn(|i| (i as f32 - 3.5) * 1.7);
        let b = F32s::<8>::from_fn(|i| (i as f32 + 0.25) * -0.9);
        for i in 0..8 {
            assert_eq!((a + b).0[i], a.0[i] + b.0[i]);
            assert_eq!((a - b).0[i], a.0[i] - b.0[i]);
            assert_eq!((a * b).0[i], a.0[i] * b.0[i]);
            assert_eq!((a / b).0[i], a.0[i] / b.0[i]);
            assert_eq!((-a).0[i], -a.0[i]);
            assert_eq!(a.min(b).0[i], a.0[i].min(b.0[i]));
            assert_eq!(a.max(b).0[i], a.0[i].max(b.0[i]));
            assert_eq!(a.clamp(-2.0, 2.0).0[i], a.0[i].clamp(-2.0, 2.0));
            assert_eq!(a.abs().0[i], a.0[i].abs());
        }
    }

    #[test]
    fn loads_stores_and_tails() {
        let src = [1.0f32, 2.0, 3.0];
        let v = F32s::<8>::load_or(&src, 9.0);
        assert_eq!(v.0, [1.0, 2.0, 3.0, 9.0, 9.0, 9.0, 9.0, 9.0]);
        let mut dst = [0.0f32; 8];
        v.store(&mut dst);
        assert_eq!(dst, v.0);
        let w = F64s::<4>::load(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(w.0, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn masks_and_select() {
        let a = F32s::<4>([1.0, -2.0, 3.0, f32::NAN]);
        let z = F32s::<4>::splat(0.0);
        let m = a.gt(z);
        assert_eq!(m.0, [true, false, true, false], "NaN compares false, like scalar");
        assert!(m.any());
        assert!(!m.all());
        let sel = m.select_f32(F32s::splat(1.0), F32s::splat(-1.0));
        assert_eq!(sel.0, [1.0, -1.0, 1.0, -1.0]);
        assert!((!m | m).all());
        assert!(!(m & !m).any());
        // lt/ge/le agree with scalar comparisons
        assert_eq!(a.lt(z).0, [false, true, false, false]);
        assert_eq!(a.ge(z).0, [true, false, true, false]);
        assert_eq!(a.le(z).0, [false, true, false, false]);
    }

    #[test]
    fn lane_pass_widths_resolve() {
        assert_eq!(LanePass::Scalar.width(), 1);
        assert_eq!(LanePass::Width4.width(), 4);
        assert_eq!(LanePass::Width8.width(), 8);
        let w = LanePass::Auto.width();
        assert!(w == 1 || w == 4 || w == 8, "auto resolved to {w}");
        for s in ["1", "4", "8", "auto"] {
            let lp: LanePass = s.parse().unwrap();
            assert_eq!(lp.to_string(), s);
        }
        assert_eq!("scalar".parse::<LanePass>().unwrap(), LanePass::Scalar);
        assert!("16".parse::<LanePass>().is_err());
    }

    #[test]
    fn ulp_distance_is_a_metric_on_floats() {
        assert_eq!(ulp_dist_f32(1.0, 1.0), 0);
        assert_eq!(ulp_dist_f32(0.0, -0.0), 0);
        assert_eq!(ulp_dist_f32(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_dist_f32(-1.0, f32::from_bits((-1.0f32).to_bits() + 1)), 1);
        // one ulp below +0 is the smallest negative subnormal
        assert_eq!(ulp_dist_f32(f32::from_bits(0x8000_0001), 0.0), 1);
        assert!(ulp_dist_f32(1.0, 2.0) > 1_000_000);
    }

    #[test]
    fn dot_matches_reference_within_budget_and_axpy_bitwise() {
        use crate::rng::Pcg32;
        let mut rng = Pcg32::new(7, 7);
        for n in [0usize, 1, 7, 8, 9, 31, 64, 200] {
            let a: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
            let exact: f64 =
                a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let mag: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let bound = 2.0 * (n.max(1) as f64) * f64::from(f32::EPSILON) * mag + 1e-12;
            assert!((dot_f32(&a, &b) as f64 - exact).abs() <= bound, "n={n}");
            assert!((dot_ref_f32(&a, &b) as f64 - exact).abs() <= bound, "n={n}");
            // dispatcher must agree with the portable body bitwise
            assert_eq!(dot_f32_portable(&a, &b), dot_f32(&a, &b), "n={n}");

            // axpy is elementwise: bitwise equal to the scalar loop
            let x: Vec<f32> = (0..n).map(|_| rng.range(-2.0, 2.0)).collect();
            let mut y1: Vec<f32> = (0..n).map(|_| rng.range(-2.0, 2.0)).collect();
            let mut y2 = y1.clone();
            let s = rng.range(-1.5, 1.5);
            axpy_f32(s, &x, &mut y1);
            for i in 0..n {
                y2[i] += s * x[i];
            }
            assert_eq!(y1, y2, "n={n}");
        }
    }

    #[test]
    fn gemm_bt_matches_f64_reference_within_budget() {
        use crate::rng::Pcg32;
        let mut rng = Pcg32::new(13, 2);
        // Shapes straddling the output tile (63/64/65) and degenerate
        // dims; bsz covers single-row (GEMV shape) and batched.
        for &(bsz, d_in, d_out) in
            &[(1usize, 8usize, 1usize), (3, 5, 63), (2, 64, 64), (4, 17, 65), (1, 1, 130)]
        {
            let x: Vec<f32> = (0..bsz * d_in).map(|_| rng.range(-1.0, 1.0)).collect();
            let wt: Vec<f32> = (0..d_out * d_in).map(|_| rng.range(-1.0, 1.0)).collect();
            let bias: Vec<f32> = (0..d_out).map(|_| rng.range(-0.5, 0.5)).collect();
            let mut out = vec![0.0f32; bsz * d_out];
            gemm_bt_f32(&x, &wt, &bias, &mut out, bsz, d_in, d_out);
            for i in 0..bsz {
                for o in 0..d_out {
                    let exact: f64 = bias[o] as f64
                        + (0..d_in)
                            .map(|k| x[i * d_in + k] as f64 * wt[o * d_in + k] as f64)
                            .sum::<f64>();
                    let mag: f64 = bias[o].abs() as f64
                        + (0..d_in)
                            .map(|k| (x[i * d_in + k] as f64 * wt[o * d_in + k] as f64).abs())
                            .sum::<f64>();
                    let bound = 2.0
                        * ((d_in + 1).max(1) as f64)
                        * f64::from(f32::EPSILON)
                        * mag
                        + 1e-12;
                    let got = out[i * d_out + o] as f64;
                    assert!(
                        (got - exact).abs() <= bound,
                        "bsz={bsz} d_in={d_in} d_out={d_out} i={i} o={o}"
                    );
                }
            }
        }
    }

    #[test]
    fn lane_tanh_is_bitwise_the_scalar_twin() {
        let v = F32s::<8>::from_fn(|i| (i as f32 - 3.5) * 2.3);
        let t = v.tanh();
        for i in 0..8 {
            assert_eq!(t.0[i].to_bits(), math::tanh_f32(v.0[i]).to_bits());
        }
    }
}
