//! Deterministic, branchless f32 elementary functions — the **shared
//! twins** of the lane-pass trig and the f32 inference `tanh`.
//!
//! # Why not libm
//!
//! `f32::sin` dispatches to the platform libm: a scalar call per lane
//! that the auto-vectorizer cannot touch, and whose exact results vary
//! across libm versions. The classic-control dynamics are trig-bound
//! (CartPole's `sin_cos`, Pendulum's `sin`, Acrobot's RK4 full of
//! `cos`), so a SIMD lane pass that still made one libm call per lane
//! would win almost nothing. These kernels replace libm in the shared
//! dynamics functions of [`crate::envs::classic`], which keeps the
//! scalar envs and every SIMD lane width **bitwise identical**: the
//! vector paths ([`super::F32s::sin_cos`]) loop lanes over the *same*
//! inline function, whose body is branchless straight-line arithmetic
//! the vectorizer handles.
//!
//! # Accuracy
//!
//! Argument reduction and the polynomial kernel are evaluated in f64
//! (promote → reduce → fdlibm minimax polynomials over |r| ≤ π/4 →
//! demote), so the f64 result carries ~1e-16 relative error and the
//! demotion to f32 is the correctly-rounded value except in
//! double-rounding near-ties. Net: **≤ 1 ULP** from the
//! correctly-rounded f32 result for |x| up to ~1e6 (the parity suite
//! asserts this budget against the f64 libm reference); the envs see
//! |x| ≲ 100.
//!
//! Determinism: no FMA, no libm, no lookup tables — pure f64 `+ - *`
//! with fixed constants, identical on every platform and lane width.
//!
//! # The `tanh` twin
//!
//! [`tanh_f32`] serves the native backend's f32 inference fast path
//! (`runtime::native::forward_f32`), where `v.tanh()` was one scalar
//! libm call per hidden unit — the last non-vectorizable op in the
//! batched forward pass. Same construction discipline as the trig:
//! promote to f64, branchless Cody–Waite reduction (base 2 this time),
//! polynomial kernel, demote. Documented budget: **≤ 2 ULP** vs the
//! demoted f64 libm `tanh` over all finite f32 inputs (asserted by the
//! in-file test and `tests/simd_parity.rs`); in practice the analysis
//! below gives ≤ 1 ULP away from double-rounding near-ties. The f64
//! training path keeps calling libm `tanh`, so PPO head branches that
//! compare f64 activations can never flip because of this twin.

/// 2/π in f64.
const FRAC_2_PI: f64 = std::f64::consts::FRAC_2_PI;
/// π/2 split for Cody–Waite reduction (fdlibm's `pio2_1`/`pio2_1t`):
/// `PIO2_HI` carries 33 significant bits, so `n · PIO2_HI` is **exact**
/// for |n| < 2^20 and `x − n·PIO2_HI − n·PIO2_LO` loses no accuracy to
/// cancellation — the reduced argument is good to ~1e-20, far below
/// one f32 ULP even when `sin` lands near zero.
const PIO2_HI: f64 = 1.570_796_326_734_125_6;
const PIO2_LO: f64 = 6.077_100_506_506_192e-11;
/// Round-to-nearest magic: adding/subtracting 1.5·2^52 rounds an f64
/// with |x| < 2^51 to an integer (ties to even) without a branch or an
/// intrinsic — trivially vectorizable.
const ROUND_MAGIC: f64 = 6_755_399_441_055_744.0;

// fdlibm __kernel_sin coefficients (sin(r) ≈ r + r³·poly(r²), |r| ≤ π/4;
// shortest-roundtrip decimal forms of the exact f64 bit patterns).
const S1: f64 = -0.166_666_666_666_666_32;
const S2: f64 = 0.008_333_333_333_322_49;
const S3: f64 = -0.000_198_412_698_298_579_5;
const S4: f64 = 2.755_731_370_707_006_8e-6;
const S5: f64 = -2.505_076_025_340_686_3e-8;
const S6: f64 = 1.589_690_995_211_55e-10;

// fdlibm __kernel_cos coefficients (cos(r) ≈ 1 − r²/2 + r⁴·poly(r²)).
const C1: f64 = 0.041_666_666_666_666_6;
const C2: f64 = -0.001_388_888_888_887_411;
const C3: f64 = 2.480_158_728_947_673e-5;
const C4: f64 = -2.755_731_435_139_066_3e-7;
const C5: f64 = 2.087_572_321_298_175e-9;
const C6: f64 = -1.135_964_755_778_819_5e-11;

/// `sin(r)` for reduced `|r| ≤ π/4 + ε` (f64 in, f64 out).
#[inline(always)]
fn kernel_sin(r: f64) -> f64 {
    let z = r * r;
    let p = S1 + z * (S2 + z * (S3 + z * (S4 + z * (S5 + z * S6))));
    r + r * z * p
}

/// `cos(r)` for reduced `|r| ≤ π/4 + ε`.
#[inline(always)]
fn kernel_cos(r: f64) -> f64 {
    let z = r * r;
    let p = C1 + z * (C2 + z * (C3 + z * (C4 + z * (C5 + z * C6))));
    1.0 - 0.5 * z + z * z * p
}

/// Simultaneous `(sin x, cos x)` for f32 `x` — the scalar twin of the
/// lane-pass trig (see module docs). Branchless: quadrant handling is
/// a pair of selects, so a per-lane loop over this function vectorizes.
///
/// Domain: |x| < 2^31 (far beyond any env state; non-finite inputs
/// yield NaN like libm).
#[inline(always)]
pub fn sin_cos_f32(x: f32) -> (f32, f32) {
    let xd = x as f64;
    // n = round(x · 2/π), branchless (ties-to-even is fine: any
    // consistent integer works, the kernels are valid slightly past π/4).
    let n = (xd * FRAC_2_PI + ROUND_MAGIC) - ROUND_MAGIC;
    let r = (xd - n * PIO2_HI) - n * PIO2_LO;
    // quadrant = n mod 4 (two's-complement & handles negatives).
    let q = (n as i64) & 3;
    let s = kernel_sin(r);
    let c = kernel_cos(r);
    // q=0: (s, c)   q=1: (c, −s)   q=2: (−s, −c)   q=3: (−c, s)
    let swap = (q & 1) != 0;
    let (us, uc) = if swap { (c, s) } else { (s, c) };
    let sin_neg = (q & 2) != 0;
    let cos_neg = ((q + 1) & 2) != 0;
    let sv = if sin_neg { -us } else { us };
    let cv = if cos_neg { -uc } else { uc };
    (sv as f32, cv as f32)
}

/// `sin(x)` via the shared kernel (see [`sin_cos_f32`]).
#[inline(always)]
pub fn sin_f32(x: f32) -> f32 {
    sin_cos_f32(x).0
}

/// `cos(x)` via the shared kernel (see [`sin_cos_f32`]).
#[inline(always)]
pub fn cos_f32(x: f32) -> f32 {
    sin_cos_f32(x).1
}

/// log2(e) in f64.
const LOG2_E: f64 = std::f64::consts::LOG2_E;
/// ln 2 split for Cody–Waite reduction (fdlibm's `ln2_hi`/`ln2_lo`):
/// `LN2_HI`'s low 20 mantissa bits are zero, so `n · LN2_HI` is exact
/// for the |n| ≤ 58 this file ever produces and the reduced argument
/// `x − n·LN2_HI − n·LN2_LO` carries no cancellation error.
const LN2_HI: f64 = 0.693_147_180_369_123_8;
const LN2_LO: f64 = 1.908_214_929_270_587_7e-10;

/// Threshold below which `tanh(x)` is taken as `x` (2⁻¹⁷). At the
/// crossover both paths agree to ~2e-11 relative — three orders of
/// magnitude under half an f32 ULP — so the select cannot introduce a
/// visible seam; below it, the identity avoids the `1 − (1 − t)`
/// cancellation that would otherwise blow up as x → 0.
const TANH_SMALL: f64 = 7.62939453125e-6;

/// `e^x` for `x ∈ [0, 45]` (f64 in, f64 out), branchless.
///
/// `n = round(x · log2 e)` via the magic-constant trick, Cody–Waite
/// reduction to `|r| ≤ ln2/2`, degree-9 Taylor kernel (max relative
/// error ~7e-12, far under the demoted-f32 half-ULP of 6e-8), then an
/// exact scale by `2^n` built from bits. NaN propagates: `NaN as i64`
/// is 0 in Rust, so the scale is 1.0 and `NaN · 1.0 = NaN`.
#[inline(always)]
fn exp_pos(x: f64) -> f64 {
    let n = (x * LOG2_E + ROUND_MAGIC) - ROUND_MAGIC;
    let r = (x - n * LN2_HI) - n * LN2_LO;
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (1.0 / 6.0
                    + r * (1.0 / 24.0
                        + r * (1.0 / 120.0
                            + r * (1.0 / 720.0
                                + r * (1.0 / 5040.0
                                    + r * (1.0 / 40320.0 + r * (1.0 / 362880.0)))))))));
    // 2^n assembled directly in the exponent field — exact, no powi.
    let scale = f64::from_bits((((n as i64) + 1023) << 52) as u64);
    p * scale
}

/// `tanh(x)` for f32 `x` — the scalar twin of the lane-pass activation
/// (see module docs; the vector path is [`super::F32s::tanh`]).
/// Branchless: the range splits compile to selects, so a per-lane loop
/// over this function vectorizes.
///
/// Evaluation: `tanh(x) = sign(x) · (1 − 2 / (e^{2|x|} + 1))` in f64,
/// with `2|x|` saturated at 40 (where `1 − 2e⁻⁴⁰` already rounds to
/// 1.0 in f64, let alone f32 — and the comparison keeps NaN off the
/// clamp) and `tanh(x) = x` below [`TANH_SMALL`]. Signed zero and the
/// odd symmetry come from `copysign`, so `tanh(-x) == -tanh(x)`
/// bitwise and `tanh(-0.0) == -0.0`.
///
/// Budget: **≤ 2 ULP** vs `((x as f64).tanh()) as f32` over all finite
/// inputs (documented headroom; the error analysis in the module docs
/// bounds every term well under 1 f32 ULP away from near-ties).
#[inline(always)]
pub fn tanh_f32(x: f32) -> f32 {
    let xd = x as f64;
    let a = xd.abs();
    let d = a + a;
    let d = if d > 40.0 { 40.0 } else { d };
    let e = exp_pos(d);
    let big = 1.0 - 2.0 / (e + 1.0);
    let t = if a < TANH_SMALL { a } else { big };
    f64::copysign(t, xd) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    use crate::simd::ulp_dist_f32 as ulp_dist;

    #[test]
    fn matches_f64_libm_within_one_ulp() {
        let mut rng = Pcg32::new(42, 1);
        for _ in 0..20_000 {
            let x = rng.range(-100.0, 100.0);
            let (s, c) = sin_cos_f32(x);
            let rs = (x as f64).sin() as f32;
            let rc = (x as f64).cos() as f32;
            assert!(ulp_dist(s, rs) <= 1, "sin({x}): {s} vs {rs}");
            assert!(ulp_dist(c, rc) <= 1, "cos({x}): {c} vs {rc}");
        }
        // wider range (pendulum theta never exceeds ~100, but be safe)
        for _ in 0..2_000 {
            let x = rng.range(-10_000.0, 10_000.0);
            assert!(ulp_dist(sin_f32(x), (x as f64).sin() as f32) <= 1, "sin({x})");
            assert!(ulp_dist(cos_f32(x), (x as f64).cos() as f32) <= 1, "cos({x})");
        }
    }

    #[test]
    fn exact_points_and_symmetry() {
        assert_eq!(sin_cos_f32(0.0), (0.0, 1.0));
        let (s, c) = sin_cos_f32(std::f32::consts::FRAC_PI_2);
        assert!((s - 1.0).abs() < 1e-7 && c.abs() < 1e-7);
        let mut rng = Pcg32::new(3, 3);
        for _ in 0..1_000 {
            let x = rng.range(-50.0, 50.0);
            // sin is odd, cos is even — bitwise, since the kernel is
            // sign-symmetric (n and q negate coherently).
            assert_eq!(sin_f32(-x), -sin_f32(x), "x={x}");
            assert_eq!(cos_f32(-x), cos_f32(x), "x={x}");
            // sin/cos components agree with the combined call bitwise
            let (s, c) = sin_cos_f32(x);
            assert_eq!(s, sin_f32(x));
            assert_eq!(c, cos_f32(x));
        }
    }

    #[test]
    fn nan_and_inf_propagate() {
        assert!(sin_f32(f32::NAN).is_nan());
        assert!(cos_f32(f32::NAN).is_nan());
        assert!(sin_f32(f32::INFINITY).is_nan());
        assert!(cos_f32(f32::NEG_INFINITY).is_nan());
    }

    #[test]
    fn tanh_matches_f64_libm_within_budget() {
        let mut rng = Pcg32::new(11, 4);
        // The activation range the MLP actually sees (pre-activations
        // are a few units wide), plus wide and tiny magnitudes to cover
        // the saturation clamp and the small-x identity path.
        for (lo, hi) in [(-4.0f32, 4.0), (-30.0, 30.0), (-1e-3, 1e-3)] {
            for _ in 0..20_000 {
                let x = rng.range(lo, hi);
                let got = tanh_f32(x);
                let want = ((x as f64).tanh()) as f32;
                assert!(
                    ulp_dist(got, want) <= 2,
                    "tanh({x}): {got} vs {want}"
                );
            }
        }
        // Denormal-adjacent and huge inputs.
        for x in [1e-30f32, -1e-38, 1e-44, 50.0, -50.0, 1e30, f32::MAX] {
            let got = tanh_f32(x);
            let want = ((x as f64).tanh()) as f32;
            assert!(ulp_dist(got, want) <= 2, "tanh({x}): {got} vs {want}");
        }
    }

    #[test]
    fn tanh_edges_sign_and_saturation() {
        // Exact endpoints and signed zero.
        assert_eq!(tanh_f32(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(tanh_f32(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(tanh_f32(f32::INFINITY), 1.0);
        assert_eq!(tanh_f32(f32::NEG_INFINITY), -1.0);
        assert_eq!(tanh_f32(20.0), 1.0);
        assert_eq!(tanh_f32(-20.0), -1.0);
        assert!(tanh_f32(f32::NAN).is_nan());
        // Odd symmetry is bitwise (copysign construction).
        let mut rng = Pcg32::new(5, 9);
        for _ in 0..1_000 {
            let x = rng.range(-20.0, 20.0);
            assert_eq!(tanh_f32(-x).to_bits(), (-tanh_f32(x)).to_bits(), "x={x}");
        }
        // Monotone, bounded on a coarse sweep.
        let mut prev = -1.0f32;
        for i in 0..=400 {
            let x = -10.0 + i as f32 * 0.05;
            let t = tanh_f32(x);
            assert!((-1.0..=1.0).contains(&t));
            assert!(t >= prev, "tanh not monotone at {x}");
            prev = t;
        }
    }
}
