//! x86_64 `core::arch` intrinsics behind **runtime** feature detection
//! (dispatched from [`super::dot_f32`]; never called unless
//! `is_x86_feature_detected!("avx2")` said yes).
//!
//! The contract with the portable path is bitwise equality: the AVX2
//! kernel keeps the exact accumulation structure of
//! [`super::dot_f32_portable`] — one 8-lane accumulator updated with
//! separate mul/add (**no FMA**, which would contract and change
//! results), a lane-0..7 horizontal sum, then the sequential scalar
//! tail — so which path runs on a given machine never changes numerics,
//! only speed.

#![allow(unsafe_code)]

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// AVX2 dot product, bitwise identical to [`super::dot_f32_portable`].
///
/// # Safety
/// The caller must have verified AVX2 support at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
    const L: usize = 8;
    let n = a.len();
    let chunks = n / L;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let va = _mm256_loadu_ps(a.as_ptr().add(c * L));
        let vb = _mm256_loadu_ps(b.as_ptr().add(c * L));
        // mul then add (matching `acc + va * vb` lane-wise) — not fmadd.
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
    }
    let mut lanes = [0.0f32; L];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    // Same fixed lane order as the portable horizontal sum.
    let mut sum = 0.0f32;
    for v in lanes {
        sum += v;
    }
    for (&x, &y) in a[chunks * L..n].iter().zip(&b[chunks * L..n]) {
        sum += x * y;
    }
    sum
}

#[cfg(all(test, target_arch = "x86_64"))]
mod tests {
    use crate::rng::Pcg32;

    #[test]
    fn avx2_dot_is_bitwise_equal_to_portable() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            eprintln!("skipping: no AVX2 on this machine");
            return;
        }
        let mut rng = Pcg32::new(11, 4);
        for n in [0usize, 3, 8, 16, 17, 64, 129, 1000] {
            let a: Vec<f32> = (0..n).map(|_| rng.range(-3.0, 3.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.range(-3.0, 3.0)).collect();
            let portable = crate::simd::dot_f32_portable(&a, &b);
            // SAFETY: feature checked above.
            let avx = unsafe { super::dot_f32_avx2(&a, &b) };
            assert_eq!(portable.to_bits(), avx.to_bits(), "n={n}");
        }
    }
}
