//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so we carry a small, fast,
//! well-understood generator: PCG32 (O'Neill 2014) seeded through
//! SplitMix64. Every environment instance owns its own stream keyed by
//! `(seed, env_id)`, which makes whole-pool runs reproducible regardless
//! of thread scheduling — the property the integration tests rely on.

/// SplitMix64 — used to expand a user seed into PCG state/stream pairs.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The one place per-env RNG streams are derived. Every environment
/// family keys its lanes as `Pcg32::new(seed ^ family_salt, env_id)`:
/// the salt keeps different tasks at the same `(seed, env_id)` on
/// disjoint streams, and using `env_id` as the PCG *stream* (rather
/// than mixing it into the state) means lane `l` of a width-N kernel,
/// a width-1 kernel built with `first_env_id = l`, and a scalar env
/// with `env_id = l` all draw the identical sequence — the property
/// every cross-`ExecMode` parity test rests on.
#[inline]
pub fn env_rng(seed: u64, family_salt: u64, env_id: u64) -> Pcg32 {
    Pcg32::new(seed ^ family_salt, env_id)
}

/// PCG32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from `(seed, stream)`. Distinct streams are
    /// statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let mut sm2 = stream ^ 0xDEAD_BEEF_CAFE_F00D;
        let init_inc = splitmix64(&mut sm2) | 1;
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        let _ = rng.next_u32();
        rng
    }

    /// Next raw 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal via Box–Muller (caches nothing; two u32 draws).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_diverge() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3, "streams should be nearly disjoint, {same} collisions");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::new(1, 1);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Pcg32::new(3, 9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::new(5, 5);
        for _ in 0..10_000 {
            assert!(r.below(6) < 6);
        }
        // all values hit
        let mut seen = [false; 6];
        for _ in 0..1000 {
            seen[r.below(6) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn env_rng_is_the_salted_stream_construction() {
        // Cross-mode determinism pin: the helper must be exactly the
        // `(seed ^ salt, env_id)` construction every kernel family used
        // before it was deduplicated — and the same env_id must yield
        // the same stream no matter which execution surface derives it.
        for (seed, salt, id) in [(0u64, 0u64, 0u64), (7, 0x70656e, 3), (42, 0x6d6a63, 11)] {
            let mut a = env_rng(seed, salt, id);
            let mut b = Pcg32::new(seed ^ salt, id);
            for _ in 0..100 {
                assert_eq!(a.next_u32(), b.next_u32());
            }
        }
        // salt 0 is the identity: families that predate salting keep
        // their historical streams bitwise.
        let mut a = env_rng(9, 0, 2);
        let mut b = Pcg32::new(9, 2);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11, 2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
