//! The SIMD parity layer: every lane width of every SIMD lane pass is
//! pinned against its scalar reference with an **explicit, asserted
//! budget** — and for the env kernels that budget is **zero ULPs**.
//!
//! Contract (see `src/simd/mod.rs`):
//! - The env-kernel lane passes are reassociation-free — the lane-group
//!   dynamics apply the identical operations in the identical order as
//!   the scalar dynamics (shared trig kernel included), so widths 1, 4
//!   and 8 must agree **bitwise** across random env counts (masked
//!   tails), random seeds, natural auto-resets and forced mid-batch
//!   resets. Asserted as `ulp == 0` per element, per step.
//! - The only reassociating op is the reduction `simd::dot_f32`
//!   (8 partial sums + fixed-order horizontal sum). Its divergence from
//!   the strictly-sequential `dot_ref_f32` is bounded by the standard
//!   forward-error bound `|fl(x·y) − x·y| ≤ γ_n Σ|x_i y_i|`; for
//!   positive inputs that is a **relative** bound, asserted here in
//!   ULPs (≤ 2n + margin); for mixed signs it is asserted absolutely
//!   against an f64 reference.
//! - The shared trig twins (`simd::math`) sit within 1 ULP of the f64
//!   libm reference, and their lane-group form is bitwise equal to the
//!   scalar twin. The `tanh` twin (the f32 inference activation) sits
//!   within 2 ULPs of demoted f64 libm under the same lane-exactness
//!   rule.
//! - The blocked transposed-weights GEMM (`simd::gemm_bt_f32`, the f32
//!   forward's matmul) computes each output element as one `dot_f32`,
//!   so per element it inherits the γ_n dot budget; asserted here
//!   against the sequential axpy GEMV (`runtime::native::affine_f32`)
//!   it replaced, with the explicit bound, across shapes and lane
//!   widths.
//!
//! The `simd-parity` CI job additionally re-runs this suite (and the
//! scalar-vs-vector suite) with `ENVPOOL_LANE_WIDTH` forced to 1, 4 and
//! 8 so the `Auto` resolution path is exercised at every width.
//!
//! Scope: this 0-ULP layer covers the classic-control kernels. The
//! MuJoCo walker family runs its *solver* lane-grouped since the
//! batch-resident refactor and ships under a documented tolerance
//! budget at widths > 1 — its parity layer is
//! `tests/mujoco_batch_parity.rs`.

use envpool::envs::env::Step;
use envpool::envs::registry;
use envpool::envs::vector::{SliceArena, VecEnv};
use envpool::prop::forall;
use envpool::prop_assert;
use envpool::rng::Pcg32;
use envpool::simd::{dot_f32, dot_ref_f32, math, ulp_dist_f32, LanePass};

const CLASSIC: &[&str] = &["CartPole-v1", "MountainCar-v0", "Pendulum-v1", "Acrobot-v1"];

/// Drive `widths.len()` copies of the same kernel (same task, seed and
/// lane count, different lane widths) lock-step on one action/reset
/// stream; assert 0-ULP equality of observations and rewards and exact
/// equality of flags at every step. `n` deliberately includes counts
/// that are not multiples of 4 or 8 (masked tails), and the driver
/// forces extra mid-batch resets beyond the natural episode ends.
fn check_kernel_widths(
    task: &str,
    n: usize,
    seed: u64,
    steps: usize,
    arng: &mut Pcg32,
) -> Result<(), String> {
    let widths = [LanePass::Scalar, LanePass::Width4, LanePass::Width8];
    let mut kernels: Vec<Box<dyn VecEnv>> = widths
        .iter()
        .map(|&lp| {
            let mut k = registry::make_vec_env(task, seed, 0, n).unwrap();
            k.set_lane_pass(lp);
            k
        })
        .collect();
    let spec = kernels[0].spec().clone();
    let dim = spec.obs_dim();
    let adim = spec.action_space.dim();

    let mut obs: Vec<Vec<f32>> = vec![vec![0.0f32; n * dim]; kernels.len()];
    for (k, kernel) in kernels.iter_mut().enumerate() {
        for lane in 0..n {
            kernel.reset_lane(lane, &mut obs[k][lane * dim..(lane + 1) * dim]);
        }
    }
    for k in 1..obs.len() {
        prop_assert!(obs[k] == obs[0], "{task}: reset obs diverge (width {:?})", widths[k]);
    }

    let mut mask = vec![0u8; n];
    let mut outs: Vec<Vec<Step>> = vec![vec![Step::default(); n]; kernels.len()];
    let mut actions = vec![0.0f32; n * adim];
    for t in 0..steps {
        envpool::coordinator::throughput::random_actions(
            &spec.action_space,
            n,
            arng,
            &mut actions,
        );
        // Force extra mid-batch resets (~10% of steps, one random lane)
        // on top of the natural `finished()` resets — the same mask is
        // applied to every width.
        if arng.below(10) == 0 {
            let lane = arng.below(n as u32) as usize;
            mask[lane] = 1;
        }
        for (k, kernel) in kernels.iter_mut().enumerate() {
            let mut arena = SliceArena::new(&mut obs[k], dim);
            kernel.step_batch(&actions, &mask, &mut arena, &mut outs[k]);
        }
        for k in 1..kernels.len() {
            for lane in 0..n {
                let (a, b) = (outs[0][lane], outs[k][lane]);
                prop_assert!(
                    ulp_dist_f32(a.reward, b.reward) == 0
                        && a.done == b.done
                        && a.truncated == b.truncated,
                    "{task}: step {t} lane {lane} width {:?}: {a:?} vs {b:?}",
                    widths[k]
                );
                for d in 0..dim {
                    let (x, y) = (obs[0][lane * dim + d], obs[k][lane * dim + d]);
                    prop_assert!(
                        ulp_dist_f32(x, y) == 0,
                        "{task}: step {t} lane {lane} obs[{d}] width {:?}: \
                         {x:?} vs {y:?} ({} ulp)",
                        widths[k],
                        ulp_dist_f32(x, y)
                    );
                }
            }
        }
        for lane in 0..n {
            mask[lane] = outs[0][lane].finished() as u8;
        }
    }
    Ok(())
}

#[test]
fn classic_kernels_bitwise_across_lane_widths() {
    forall("simd-classic-widths", |g| {
        let task = *g.choose(CLASSIC);
        // 1..=19 covers: below one group, exact multiples of 4 and 8,
        // and masked tails for both widths.
        let n = g.usize_in(1, 19);
        let seed = g.rng.next_u64();
        let mut arng = Pcg32::new(seed ^ 0xAC7, 1);
        check_kernel_widths(task, n, seed, 120, &mut arng)
    });
}

// NOTE: the walker family is deliberately absent from the 0-ULP layer.
// Since the batch-resident physics refactor the *constraint solver*
// runs lane-grouped, and widths > 1 ship under a documented tolerance
// budget instead of bitwise equality — that contract (width-1 bitwise
// pin vs the pre-refactor AoS stepper, widths 4/8 budget + invariants,
// masked mid-batch resets) lives in `tests/mujoco_batch_parity.rs`.

#[test]
fn pool_lane_pass_is_invisible_to_trajectories() {
    // Through the vectorized pool engine: forcing width 8 vs width 1
    // must leave every batch bitwise unchanged (PoolConfig::lane_pass
    // is a pure throughput knob).
    use envpool::pool::{EnvPool, ExecMode, PoolConfig};
    let run = |lp: LanePass| {
        let mut pool = EnvPool::make(
            PoolConfig::new("CartPole-v1")
                .num_envs(11)
                .sync()
                .num_threads(2)
                .seed(7)
                .exec_mode(ExecMode::Vectorized)
                .lane_pass(lp),
        )
        .unwrap();
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        let mut trace: Vec<f32> = Vec::new();
        for step in 0..100 {
            let ids = out.env_ids.clone();
            // per-env deterministic actions (batch order may vary)
            let actions: Vec<f32> =
                ids.iter().map(|&i| ((step + i as usize) % 2) as f32).collect();
            pool.step_into(&actions, &ids, &mut out).unwrap();
            // canonical env-id order for comparison
            let mut order: Vec<usize> = (0..out.len()).collect();
            order.sort_by_key(|&k| out.env_ids[k]);
            for &k in &order {
                trace.extend_from_slice(out.obs_row(k));
                trace.push(out.rew[k]);
            }
        }
        trace
    };
    let scalar = run(LanePass::Scalar);
    for lp in [LanePass::Width4, LanePass::Width8, LanePass::Auto] {
        assert_eq!(run(lp), scalar, "{lp} trajectory diverged from width 1");
    }
}

#[test]
fn trig_twins_within_one_ulp_of_f64_libm_and_lane_exact() {
    forall("simd-trig", |g| {
        let x = g.f32_in(-100.0, 100.0);
        let (s, c) = math::sin_cos_f32(x);
        let (rs, rc) = ((x as f64).sin() as f32, (x as f64).cos() as f32);
        prop_assert!(ulp_dist_f32(s, rs) <= 1, "sin({x}): {s} vs libm {rs}");
        prop_assert!(ulp_dist_f32(c, rc) <= 1, "cos({x}): {c} vs libm {rc}");

        // lane-group trig is the same inline function per lane: bitwise
        let xs = envpool::simd::F32s::<8>::from_fn(|i| x + i as f32 * 0.37);
        let (vs, vc) = xs.sin_cos();
        for i in 0..8 {
            let (ss, sc) = math::sin_cos_f32(xs.0[i]);
            prop_assert!(
                vs.0[i].to_bits() == ss.to_bits() && vc.0[i].to_bits() == sc.to_bits(),
                "lane {i} of sin_cos({}) diverged from the scalar twin",
                xs.0[i]
            );
        }
        Ok(())
    });
}

#[test]
fn tanh_twin_within_two_ulp_of_f64_libm_and_lane_exact() {
    // The f32 inference path's activation (`NativeNet::forward_f32`).
    // Budget 2 ULPs vs the demoted f64 libm reference (documented in
    // `simd::math`): one ULP from the twin's own exp/division error,
    // one from the double rounding f64→f32 at the boundary. The f64
    // training path keeps libm `tanh`, so this budget never moves a
    // branch decision shared between the precisions.
    forall("simd-tanh", |g| {
        // Spans the saturated region (|2x| > 40), the rational-formula
        // core, and the tiny-|x| linear path (|x| < 2⁻¹⁷).
        let x = match g.usize_in(0, 2) {
            0 => g.f32_in(-30.0, 30.0),
            1 => g.f32_in(-2.0, 2.0),
            _ => g.f32_in(-1e-4, 1e-4),
        };
        let got = math::tanh_f32(x);
        let want = ((x as f64).tanh()) as f32;
        prop_assert!(
            ulp_dist_f32(got, want) <= 2,
            "tanh({x}): {got} vs libm {want} = {} ulp",
            ulp_dist_f32(got, want)
        );
        // Odd symmetry is bitwise (copysign construction).
        prop_assert!(
            math::tanh_f32(-x).to_bits() == (-got).to_bits(),
            "tanh(-{x}) is not the bitwise negation"
        );

        // Lane-group tanh is the same inline function per lane: bitwise
        // at both hardware widths.
        let x4 = envpool::simd::F32s::<4>::from_fn(|i| x + i as f32 * 0.73);
        let x8 = envpool::simd::F32s::<8>::from_fn(|i| x - i as f32 * 0.41);
        for (i, (lane, s)) in x4.tanh().0.iter().zip(x4.0).enumerate() {
            prop_assert!(
                lane.to_bits() == math::tanh_f32(s).to_bits(),
                "W=4 lane {i} of tanh({s}) diverged from the scalar twin"
            );
        }
        for (i, (lane, s)) in x8.tanh().0.iter().zip(x8.0).enumerate() {
            prop_assert!(
                lane.to_bits() == math::tanh_f32(s).to_bits(),
                "W=8 lane {i} of tanh({s}) diverged from the scalar twin"
            );
        }
        Ok(())
    });
}

#[test]
fn blocked_gemm_matches_sequential_gemv_within_budget() {
    // `gemm_bt_f32` (blocked, transposed weights, dot-product inner
    // loop) vs `affine_f32` (sequential axpy accumulation) compute the
    // same affine map in two accumulation orders. Each is within the
    // forward-error bound |fl(y) − y| ≤ γ_{n+1}·(|b| + Σ|x_k·w_ko|)
    // of the exact element (n = d_in, +1 for the bias add), so their
    // distance is ≤ 2·γ_{n+1}·mag. THE BUDGET IS ASSERTED per element,
    // with `mag` computed in f64.
    use envpool::runtime::native::affine_f32;
    use envpool::simd::gemm_bt_f32;
    forall("simd-gemm-vs-gemv", |g| {
        let bsz = g.usize_in(1, 5);
        // d_out spans both sides of the 64-wide GEMM output tile.
        let d_in = g.usize_in(1, 80);
        let d_out = g.usize_in(1, 70);
        let x = g.vec(bsz * d_in, |g| g.f32_in(-1.0, 1.0));
        let w = g.vec(d_in * d_out, |g| g.f32_in(-1.0, 1.0)); // [d_in, d_out]
        let bias = g.vec(d_out, |g| g.f32_in(-1.0, 1.0));
        let mut wt = vec![0.0f32; d_out * d_in]; // [d_out, d_in]
        for k in 0..d_in {
            for o in 0..d_out {
                wt[o * d_in + k] = w[k * d_out + o];
            }
        }
        let mut out_gemm = vec![0.0f32; bsz * d_out];
        let mut out_gemv = vec![0.0f32; bsz * d_out];
        gemm_bt_f32(&x, &wt, &bias, &mut out_gemm, bsz, d_in, d_out);
        affine_f32(&x, &w, &bias, &mut out_gemv, bsz, d_in, d_out);
        let gamma = 2.0 * (d_in + 1) as f64 * f64::from(f32::EPSILON);
        for i in 0..bsz {
            for o in 0..d_out {
                let mag: f64 = (bias[o] as f64).abs()
                    + (0..d_in)
                        .map(|k| (x[i * d_in + k] as f64 * w[k * d_out + o] as f64).abs())
                        .sum::<f64>();
                let (a, b) = (out_gemm[i * d_out + o], out_gemv[i * d_out + o]);
                prop_assert!(
                    (a as f64 - b as f64).abs() <= gamma * mag + 1e-10,
                    "({bsz},{d_in},{d_out}) out[{i},{o}]: gemm {a} vs gemv {b} \
                     exceeds budget {}",
                    gamma * mag + 1e-10
                );
            }
        }
        Ok(())
    });
}

#[test]
fn dot_reassociation_stays_within_explicit_ulp_budget() {
    // Positive inputs: Σ|x_i y_i| = |dot|, so the forward-error bound
    // |fl(dot) − dot| ≤ γ_n·|dot| (γ_n = n·u/(1−n·u), u = 2⁻²⁴) is a
    // relative bound. Both accumulation orders satisfy it, so their
    // distance is ≤ 2·γ_n·|dot| ≤ (2n + margin) ULPs of the result.
    // THE BUDGET IS ASSERTED — not "approximately equal".
    forall("simd-dot-ulp-budget", |g| {
        let n = g.usize_in(1, 300);
        let a = g.vec(n, |g| g.f32_in(0.01, 1.0));
        let b = g.vec(n, |g| g.f32_in(0.01, 1.0));
        let simd = dot_f32(&a, &b);
        let scalar = dot_ref_f32(&a, &b);
        let budget = 2 * n as u64 + 2;
        let dist = ulp_dist_f32(simd, scalar);
        prop_assert!(
            dist <= budget,
            "n={n}: dot {simd} vs {scalar} = {dist} ulp > budget {budget}"
        );

        // Mixed signs: cancellation voids a relative bound; assert the
        // absolute γ-bound against an (effectively exact) f64 reference
        // for BOTH orders.
        let c = g.vec(n, |g| g.f32_in(-1.0, 1.0));
        let d = g.vec(n, |g| g.f32_in(-1.0, 1.0));
        let exact: f64 = c.iter().zip(&d).map(|(&x, &y)| x as f64 * y as f64).sum();
        let mag: f64 = c.iter().zip(&d).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
        let abs_budget = 2.0 * n as f64 * f64::from(f32::EPSILON) * mag + 1e-10;
        for (label, got) in [("simd", dot_f32(&c, &d)), ("scalar", dot_ref_f32(&c, &d))] {
            prop_assert!(
                (got as f64 - exact).abs() <= abs_budget,
                "n={n} {label}: |{got} - {exact}| > {abs_budget}"
            );
        }
        Ok(())
    });
}

#[test]
fn degenerate_width_one_is_the_scalar_reference() {
    // LanePass::Scalar must select the *original* per-lane loop: pin it
    // against the scalar Env directly (one lane, long horizon) so the
    // width-1 path can never silently become "SIMD with W=1".
    use envpool::envs::env::Env;
    for task in CLASSIC {
        let seed = 31;
        let mut kernel = registry::make_vec_env(task, seed, 0, 1).unwrap();
        kernel.set_lane_pass(LanePass::Scalar);
        let mut env = registry::make_env(task, seed, 0).unwrap();
        let dim = env.spec().obs_dim();
        let adim = env.spec().action_space.dim();
        let mut vobs = vec![0.0f32; dim];
        let mut sobs = vec![0.0f32; dim];
        kernel.reset_lane(0, &mut vobs);
        env.reset(&mut sobs);
        assert_eq!(vobs, sobs, "{task} reset");
        let mut mask = [0u8];
        let mut outs = [Step::default()];
        let mut arng = Pcg32::new(77, 7);
        let mut actions = vec![0.0f32; adim];
        for t in 0..300 {
            envpool::coordinator::throughput::random_actions(
                &env.spec().action_space.clone(),
                1,
                &mut arng,
                &mut actions,
            );
            {
                let mut arena = SliceArena::new(&mut vobs, dim);
                kernel.step_batch(&actions, &mask, &mut arena, &mut outs);
            }
            if mask[0] != 0 {
                env.reset(&mut sobs);
                assert_eq!(outs[0], Step::default(), "{task} step {t}");
            } else {
                let s = env.step(&actions, &mut sobs);
                assert_eq!(outs[0], s, "{task} step {t}");
            }
            assert_eq!(vobs, sobs, "{task} step {t} obs");
            mask[0] = outs[0].finished() as u8;
        }
    }
}
