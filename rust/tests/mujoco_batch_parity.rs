//! The walker parity layer for the batch-resident (`WorldBatch`)
//! physics refactor — the **relaxed, documented contract** that
//! replaced "bitwise at every width":
//!
//! 1. **Width 1 is bitwise with the pre-refactor code.** The AoS
//!    `World::step` is kept verbatim as the reference stepper; a
//!    replica of the pre-refactor scalar `WalkerEnv` built on it here
//!    must reproduce the production width-1 path (a view over the SoA
//!    batch) **exactly** — rewards, flags and observations, across
//!    seeded trajectories with auto-resets, for all three walkers and
//!    `cheetah_run`.
//! 2. **Widths 4/8 carry an asserted tolerance budget.** The lane-
//!    grouped solver rotates anchors through the deterministic trig
//!    twins instead of libm, so wide trajectories drift from width 1 —
//!    within `LANE_TOL_ABS + LANE_TOL_REL·|ref|` over the pinned short
//!    horizon, with termination/truncation flags identical and reset
//!    rows bitwise equal (resets bypass the solver).
//! 3. **Cross-width invariants** hold at every width over long random
//!    rollouts: bounded post-correction ground penetration, bounded
//!    (clamp-derived) kinetic energy, finite state after every reset,
//!    and passive stability (standing hopper, settling cheetah).
//!
//! The `simd-parity` CI job runs this suite at `ENVPOOL_LANE_WIDTH`
//! 1/4/8. If a seeded gate here trips after a solver change, see the
//! recalibration note in EXPERIMENTS.md before declaring a regression.

use envpool::envs::dmc::cheetah_run::TARGET_SPEED;
use envpool::envs::env::{Env, Step};
use envpool::envs::mujoco::batch::{LANE_TOL_ABS, LANE_TOL_REL};
use envpool::envs::mujoco::models::{self, Model};
use envpool::envs::mujoco::walker::{apply_reset_noise, make_rng};
use envpool::envs::mujoco::{DT, FRAME_SKIP};
use envpool::envs::registry;
use envpool::envs::vector::{SliceArena, VecEnv, WalkerVec};
use envpool::rng::Pcg32;
use envpool::simd::LanePass;

fn build(task: &str) -> Model {
    match task {
        "Hopper-v4" => models::hopper(),
        "HalfCheetah-v4" | "cheetah_run" => models::half_cheetah(),
        "Ant-v4" => models::ant(),
        other => panic!("unknown walker task {other}"),
    }
}

/// A faithful replica of the **pre-refactor** scalar walker env: AoS
/// `World::step` per substep, the original reward/healthy/obs
/// expressions, the original RNG stream. This is the trajectory oracle
/// the width-1 batch path must match bitwise.
struct RefWalker {
    proto: Model,
    model: Model,
    actuated: Vec<usize>,
    rng: Pcg32,
    steps: usize,
}

impl RefWalker {
    fn new(task: &str, seed: u64, env_id: u64) -> Self {
        let proto = build(task);
        let actuated = proto.world.actuated();
        RefWalker {
            model: proto.clone(),
            actuated,
            rng: make_rng(seed, env_id),
            steps: 0,
            proto,
        }
    }

    fn obs_dim(&self) -> usize {
        2 + self.actuated.len() + 3 + self.actuated.len()
    }

    fn write_obs(&self, obs: &mut [f32]) {
        let w = &self.model.world;
        let torso = &w.bodies[self.model.torso];
        let n = self.actuated.len();
        obs[0] = torso.pos.y;
        obs[1] = torso.angle - self.model.init_angle;
        for (k, &ji) in self.actuated.iter().enumerate() {
            obs[2 + k] = w.joints[ji].angle(&w.bodies);
        }
        obs[2 + n] = torso.vel.x;
        obs[3 + n] = torso.vel.y;
        obs[4 + n] = torso.omega;
        for (k, &ji) in self.actuated.iter().enumerate() {
            obs[5 + n + k] = w.joints[ji].speed(&w.bodies);
        }
    }

    fn healthy(&self) -> bool {
        let torso = &self.model.world.bodies[self.model.torso];
        if let Some((lo, hi)) = self.model.healthy_z {
            if torso.pos.y < lo || torso.pos.y > hi {
                return false;
            }
        }
        if let Some(dev) = self.model.healthy_angle_dev {
            if (torso.angle - self.model.init_angle).abs() > dev {
                return false;
            }
        }
        !self.model.world.is_bad()
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.model = self.proto.clone();
        apply_reset_noise(&mut self.model.world, &mut self.rng);
        self.steps = 0;
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> Step {
        let x_before = self.model.world.bodies[self.model.torso].pos.x;
        for _ in 0..FRAME_SKIP {
            self.model.world.step(DT, action);
        }
        let x_after = self.model.world.bodies[self.model.torso].pos.x;
        self.steps += 1;
        let forward = (x_after - x_before) / (DT * FRAME_SKIP as f32);
        let ctrl: f32 = action.iter().map(|a| a * a).sum();
        let healthy = self.healthy();
        let reward = self.model.forward_weight * forward
            + if healthy { self.model.healthy_reward } else { 0.0 }
            - self.model.ctrl_cost * ctrl;
        let done = !healthy;
        let truncated = !done && self.steps >= 1000;
        self.write_obs(obs);
        Step { reward, done, truncated }
    }
}

/// 1 — the bitwise pin: production width-1 path vs the pre-refactor
/// oracle, including auto-resets along the way.
fn check_width1_pin(task: &str, steps: usize, seed: u64) {
    let mut env = registry::make_env(task, seed, 3).unwrap();
    let mut reference = RefWalker::new(task, seed, 3);
    let dim = env.spec().obs_dim();
    assert_eq!(dim, reference.obs_dim(), "{task}: obs layout");
    let adim = env.spec().action_space.dim();
    let mut obs = vec![0.0f32; dim];
    let mut robs = vec![0.0f32; dim];
    env.reset(&mut obs);
    reference.reset(&mut robs);
    assert_eq!(obs, robs, "{task}: reset obs diverge from pre-refactor oracle");
    let shape = task == "cheetah_run";
    for t in 0..steps {
        let action: Vec<f32> = (0..adim).map(|k| ((t * 3 + k) as f32 * 0.29).sin()).collect();
        let got = env.step(&action, &mut obs);
        let mut want = reference.step(&action, &mut robs);
        if shape {
            // the dm_control shaping over the same transition
            let vx = robs[2 + adim];
            want = Step {
                reward: (vx / TARGET_SPEED).clamp(0.0, 1.0),
                done: false,
                truncated: want.truncated || want.done,
            };
        }
        assert_eq!(got, want, "{task}: step {t} diverges from pre-refactor oracle");
        assert_eq!(obs, robs, "{task}: obs {t} diverge from pre-refactor oracle");
        if got.finished() {
            env.reset(&mut obs);
            reference.reset(&mut robs);
            assert_eq!(obs, robs, "{task}: re-reset obs diverge at step {t}");
        }
    }
}

#[test]
fn width1_hopper_bitwise_reproduces_pre_refactor_trajectories() {
    check_width1_pin("Hopper-v4", 120, 31);
}

#[test]
fn width1_cheetah_bitwise_reproduces_pre_refactor_trajectories() {
    check_width1_pin("HalfCheetah-v4", 80, 32);
}

#[test]
fn width1_ant_bitwise_reproduces_pre_refactor_trajectories() {
    check_width1_pin("Ant-v4", 60, 33);
}

#[test]
fn width1_cheetah_run_bitwise_reproduces_pre_refactor_trajectories() {
    check_width1_pin("cheetah_run", 80, 34);
}

/// 2 — the tolerance budget: widths 4/8 vs width 1 over a short pinned
/// horizon, flags identical, obs/rewards within the documented budget,
/// forced mid-batch resets bitwise across widths.
#[test]
fn wide_lanes_within_documented_budget_and_flags_identical() {
    for task in ["Hopper-v4", "HalfCheetah-v4", "Ant-v4", "cheetah_run"] {
        let seed = 47;
        let n = 6;
        let widths = [LanePass::Scalar, LanePass::Width4, LanePass::Width8];
        let mut kernels: Vec<Box<dyn VecEnv>> = widths
            .iter()
            .map(|&lp| {
                let mut k = registry::make_vec_env(task, seed, 0, n).unwrap();
                k.set_lane_pass(lp);
                k
            })
            .collect();
        let dim = kernels[0].spec().obs_dim();
        let adim = kernels[0].spec().action_space.dim();
        let mut obs: Vec<Vec<f32>> = vec![vec![0.0f32; n * dim]; kernels.len()];
        let mut outs: Vec<Vec<Step>> = vec![vec![Step::default(); n]; kernels.len()];
        for (k, kernel) in kernels.iter_mut().enumerate() {
            for lane in 0..n {
                kernel.reset_lane(lane, &mut obs[k][lane * dim..(lane + 1) * dim]);
            }
        }
        for k in 1..obs.len() {
            assert_eq!(obs[k], obs[0], "{task}: reset obs must be bitwise (no solver ran)");
        }
        let mut mask = vec![0u8; n];
        for t in 0..8 {
            // mild actions keep the pinned horizon away from termination
            // boundaries, so flag equality across widths is robust
            let actions: Vec<f32> =
                (0..n * adim).map(|k| ((t * 5 + k) as f32 * 0.43).sin() * 0.5).collect();
            if t == 4 {
                mask[2] = 1; // forced mid-batch reset on lane 2
            }
            for (k, kernel) in kernels.iter_mut().enumerate() {
                let mut arena = SliceArena::new(&mut obs[k], dim);
                kernel.step_batch(&actions, &mask, &mut arena, &mut outs[k]);
            }
            for k in 1..kernels.len() {
                for lane in 0..n {
                    let (a, b) = (outs[0][lane], outs[k][lane]);
                    assert_eq!(
                        (a.done, a.truncated),
                        (b.done, b.truncated),
                        "{task}: step {t} lane {lane} flags diverge at {:?}",
                        widths[k]
                    );
                    if mask[lane] != 0 {
                        // resets bypass the solver entirely: bitwise
                        assert_eq!(b, Step::default(), "{task}: reset step {t} lane {lane}");
                        for d in 0..dim {
                            assert_eq!(
                                obs[0][lane * dim + d].to_bits(),
                                obs[k][lane * dim + d].to_bits(),
                                "{task}: reset obs {t} lane {lane} [{d}] at {:?}",
                                widths[k]
                            );
                        }
                        continue;
                    }
                    let (ra, rb) = (a.reward, b.reward);
                    assert!(
                        (ra - rb).abs() <= LANE_TOL_ABS + LANE_TOL_REL * ra.abs(),
                        "{task}: step {t} lane {lane} reward {ra} vs {rb} over budget at {:?}",
                        widths[k]
                    );
                    for d in 0..dim {
                        let (x, y) = (obs[0][lane * dim + d], obs[k][lane * dim + d]);
                        assert!(
                            (x - y).abs() <= LANE_TOL_ABS + LANE_TOL_REL * x.abs(),
                            "{task}: step {t} lane {lane} obs[{d}] {x} vs {y} over budget at {:?}",
                            widths[k]
                        );
                    }
                }
            }
            for lane in 0..n {
                mask[lane] = outs[0][lane].finished() as u8;
            }
        }
    }
}

/// 3a — per-width invariants over long random rollouts with
/// auto-resets: bounded post-correction penetration, bounded kinetic
/// energy, finite state after resets.
#[test]
fn solver_invariants_hold_at_every_width() {
    use envpool::envs::mujoco::walker::Task;
    // Loose sanity bounds documented with the contract: penetration is
    // Baumgarte-corrected toward SLOP (not projected), so transient
    // impact depths well above SLOP are legitimate; kinetic energy is
    // bounded by the MAX_SPEED/MAX_OMEGA clamps.
    const PENETRATION_BOUND: f32 = 0.2;
    const ENERGY_BOUND: f32 = 1e5;
    for task in [Task::Hopper, Task::HalfCheetah] {
        for width in [LanePass::Scalar, LanePass::Width4, LanePass::Width8] {
            let n = 5;
            let mut kernel = WalkerVec::new(task, 91, 0, n);
            kernel.set_lane_pass(width);
            let dim = kernel.spec().obs_dim();
            let adim = kernel.spec().action_space.dim();
            let mut obs = vec![0.0f32; n * dim];
            for lane in 0..n {
                kernel.reset_lane(lane, &mut obs[lane * dim..(lane + 1) * dim]);
                assert!(!kernel.batch().lane_is_bad(lane), "{task:?} {width}: bad after reset");
            }
            let mut outs = vec![Step::default(); n];
            let mut mask = vec![0u8; n];
            let mut arng = Pcg32::new(0xD1CE, 5);
            for t in 0..150 {
                let actions: Vec<f32> =
                    (0..n * adim).map(|_| arng.range(-1.0, 1.0)).collect();
                {
                    let mut arena = SliceArena::new(&mut obs, dim);
                    kernel.step_batch(&actions, &mask, &mut arena, &mut outs);
                }
                for lane in 0..n {
                    if mask[lane] != 0 {
                        assert!(
                            !kernel.batch().lane_is_bad(lane),
                            "{task:?} {width}: lane {lane} bad after auto-reset"
                        );
                    } else if !outs[lane].done {
                        // healthy lanes obey the physical bounds; an
                        // unhealthy lane (incl. any non-finite blowup)
                        // terminates and resets on the next step.
                        let pen = kernel.batch().max_penetration(lane);
                        assert!(
                            pen <= PENETRATION_BOUND,
                            "{task:?} {width}: step {t} lane {lane} penetration {pen}"
                        );
                        let ke = kernel.batch().kinetic_energy(lane);
                        assert!(
                            ke.is_finite() && ke <= ENERGY_BOUND,
                            "{task:?} {width}: step {t} lane {lane} energy {ke}"
                        );
                    }
                    mask[lane] = outs[lane].finished() as u8;
                }
            }
        }
    }
}

/// 3b — passive stability at every width: the standing hopper stays up
/// under zero action, and the cheetah settles to (near) rest without
/// energy injection from the lane-grouped solver.
#[test]
fn passive_stability_at_every_width() {
    use envpool::envs::mujoco::walker::Task;
    for width in [LanePass::Scalar, LanePass::Width4, LanePass::Width8] {
        // hopper: still standing after 1.0 s (models.rs pins ~1.5 s for
        // the AoS path; the tolerance contract must not change the
        // qualitative behavior)
        let mut hopper = WalkerVec::new(Task::Hopper, 5, 0, 2);
        hopper.set_lane_pass(width);
        let dim = hopper.spec().obs_dim();
        let mut obs = vec![0.0f32; 2 * dim];
        for lane in 0..2 {
            hopper.reset_lane(lane, &mut obs[lane * dim..(lane + 1) * dim]);
        }
        let mut outs = vec![Step::default(); 2];
        let mask = vec![0u8; 2];
        let actions = vec![0.0f32; 2 * 3];
        for _ in 0..20 {
            let mut arena = SliceArena::new(&mut obs, dim);
            hopper.step_batch(&actions, &mask, &mut arena, &mut outs);
        }
        for lane in 0..2 {
            let z = obs[lane * dim];
            assert!(z > 0.7, "{width}: hopper lane {lane} fell during passive stand, z={z}");
        }

        // cheetah: settles to low kinetic energy (bounded energy drift —
        // the split position correction must not pump energy at any
        // lane width)
        let mut cheetah = WalkerVec::new(Task::HalfCheetah, 6, 0, 3);
        cheetah.set_lane_pass(width);
        let cdim = cheetah.spec().obs_dim();
        let mut cobs = vec![0.0f32; 3 * cdim];
        for lane in 0..3 {
            cheetah.reset_lane(lane, &mut cobs[lane * cdim..(lane + 1) * cdim]);
        }
        let mut couts = vec![Step::default(); 3];
        let cmask = vec![0u8; 3];
        let cact = vec![0.0f32; 3 * 6];
        for t in 0..120 {
            {
                let mut arena = SliceArena::new(&mut cobs, cdim);
                cheetah.step_batch(&cact, &cmask, &mut arena, &mut couts);
            }
            for lane in 0..3 {
                let ke = cheetah.batch().kinetic_energy(lane);
                assert!(ke.is_finite() && ke < 200.0, "{width}: settle t={t} ke={ke}");
                if t >= 110 {
                    assert!(ke < 2.0, "{width}: cheetah lane {lane} not settled, ke={ke}");
                }
                assert!(
                    cheetah.batch().max_penetration(lane) <= 0.2,
                    "{width}: settle penetration"
                );
            }
        }
    }
}
