//! Stress and drop-safety tests for the two queues at the heart of the
//! pool: multi-producer multi-consumer interleavings on the
//! `ActionBufferQueue`, torn-write detection on the `StateBufferQueue`,
//! and `Drop`-counting payloads proving that dropping a partially full
//! queue neither leaks nor double-drops items.

use envpool::envs::registry;
use envpool::pool::action_queue::ActionBufferQueue;
use envpool::pool::chunked::{Chunk, ChunkedThreadPool};
use envpool::pool::state_queue::StateBufferQueue;
use envpool::pool::{EnvPool, ExecMode, PoolConfig};
use envpool::Error;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

#[test]
fn mpmc_every_item_delivered_exactly_once() {
    // 4 producers × 4 consumers over a small buffer: heavy wrap-around
    // and contention; the multiset of delivered items must be exact.
    let q: Arc<ActionBufferQueue<usize>> = Arc::new(ActionBufferQueue::new(32));
    let n_producers = 4;
    let n_consumers = 4;
    let per_producer = 5_000usize;
    let total = n_producers * per_producer;

    let mut consumers = Vec::new();
    for _ in 0..n_consumers {
        let q = q.clone();
        consumers.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            loop {
                let v = q.dequeue();
                if v == usize::MAX {
                    return got;
                }
                got.push(v);
            }
        }));
    }
    let mut producers = Vec::new();
    for p in 0..n_producers {
        let q = q.clone();
        producers.push(std::thread::spawn(move || {
            for i in 0..per_producer {
                let v = p * per_producer + i;
                while q.enqueue(v).is_err() {
                    std::thread::yield_now();
                }
            }
        }));
    }
    for h in producers {
        h.join().unwrap();
    }
    for _ in 0..n_consumers {
        while q.enqueue(usize::MAX).is_err() {
            std::thread::yield_now();
        }
    }
    let mut seen = vec![false; total];
    for h in consumers {
        for v in h.join().unwrap() {
            assert!(!seen[v], "item {v} delivered twice");
            seen[v] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "items lost");
}

/// Payload whose drops are counted per id: `counts[id]` must end at
/// exactly 1 for every created token (0 = leak, 2 = double drop).
struct DropToken {
    id: usize,
    counts: Arc<Vec<AtomicU32>>,
}

impl Drop for DropToken {
    fn drop(&mut self) {
        self.counts[self.id].fetch_add(1, Ordering::SeqCst);
    }
}

fn new_counts(n: usize) -> Arc<Vec<AtomicU32>> {
    Arc::new((0..n).map(|_| AtomicU32::new(0)).collect())
}

fn assert_all_dropped_once(counts: &[AtomicU32]) {
    for (id, c) in counts.iter().enumerate() {
        let c = c.load(Ordering::SeqCst);
        assert_eq!(c, 1, "token {id} dropped {c} times (0 = leak, >1 = double drop)");
    }
}

#[test]
fn dropping_partially_full_queue_frees_every_item_exactly_once() {
    // Fill 12 of 16 slots, consume 5 (dropping the results), then drop
    // the queue with 7 items still inside.
    let total = 12;
    let counts = new_counts(total);
    {
        let q: ActionBufferQueue<DropToken> = ActionBufferQueue::new(16);
        for id in 0..total {
            q.enqueue(DropToken { id, counts: counts.clone() }).unwrap();
        }
        for _ in 0..5 {
            drop(q.try_dequeue().unwrap());
        }
        // q dropped here with 7 live items
    }
    assert_all_dropped_once(&counts);
}

#[test]
fn dropping_wrapped_queue_frees_every_item_exactly_once() {
    // Cycle the ring several times so live items straddle the wrap
    // point, then drop mid-flight.
    let total = 40;
    let counts = new_counts(total);
    {
        let q: ActionBufferQueue<DropToken> = ActionBufferQueue::new(8);
        let mut next = 0usize;
        // keep ~5 items resident while cycling through all ids
        for _ in 0..5 {
            q.enqueue(DropToken { id: next, counts: counts.clone() }).unwrap();
            next += 1;
        }
        while next < total {
            drop(q.try_dequeue().unwrap());
            q.enqueue(DropToken { id: next, counts: counts.clone() }).unwrap();
            next += 1;
        }
        // 5 items alive in the ring at drop time
    }
    assert_all_dropped_once(&counts);
}

#[test]
fn concurrent_producers_then_drop_queue_with_residue() {
    // Multi-threaded producers and a consumer that quits early: whatever
    // is left in the queue must still be freed exactly once.
    let n_producers = 4;
    let per_producer = 1_000;
    let total = n_producers * per_producer;
    let counts = new_counts(total);
    {
        let q: Arc<ActionBufferQueue<DropToken>> = Arc::new(ActionBufferQueue::new(64));
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let q = q.clone();
            let counts = counts.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    let id = p * per_producer + i;
                    let mut tok = DropToken { id, counts: counts.clone() };
                    loop {
                        match q.enqueue(tok) {
                            Ok(()) => break,
                            Err(back) => {
                                tok = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            }));
        }
        // Consume all but a residue that fits the buffer (so producers
        // can always finish), dropping results on the floor.
        let residue = 40;
        let mut consumed = 0;
        while consumed < total - residue {
            if let Some(tok) = q.try_dequeue() {
                drop(tok);
                consumed += 1;
            } else {
                std::thread::yield_now();
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        // Dropping q frees the ~`residue` live items.
    }
    assert_all_dropped_once(&counts);
}

#[test]
fn state_queue_mpmc_blocks_are_never_torn_across_many_rounds() {
    // 4 writers hammer a small block ring (forcing recycling) while the
    // consumer checks that every row is internally consistent: the whole
    // observation row must carry the writer's tag.
    let writers = 4;
    let per_writer = 2_000u32;
    let q = Arc::new(StateBufferQueue::new(16, 4, 24));
    let handles: Vec<_> = (0..writers as u32)
        .map(|w| {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..per_writer {
                    let tag = w * per_writer + i;
                    let t = q.acquire().unwrap();
                    q.write(t, tag, tag as f32, i % 7 == 0, i % 11 == 0, |obs| {
                        obs.fill(tag as f32);
                    });
                }
            })
        })
        .collect();
    let mut out = q.make_output();
    let mut seen = std::collections::HashSet::new();
    let rounds = writers as u32 * per_writer / 4;
    for _ in 0..rounds {
        q.recv_into(&mut out).unwrap();
        for i in 0..out.len() {
            let tag = out.env_ids[i];
            assert!(seen.insert(tag), "row {tag} delivered twice");
            assert!(out.obs_row(i).iter().all(|&x| x == tag as f32), "torn row {tag}");
            assert_eq!(out.rew[i], tag as f32, "scalar lane mismatch for {tag}");
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(seen.len(), (writers as u32 * per_writer) as usize);
}

#[test]
fn chunked_pool_clamps_surplus_workers_to_chunk_count() {
    // Regression: K = ceil(N/threads) can yield fewer chunks than
    // requested workers; surplus workers must not be spawned (they would
    // sit pinned and idle forever).
    let n = 3;
    let chunk_size = 1; // 3 chunks
    let states = Arc::new(StateBufferQueue::new(n, n, 4));
    let chunks: Vec<Chunk> = (0..n)
        .map(|c| {
            let envs = registry::make_vec_env("CartPole-v1", 9, c as u64, chunk_size).unwrap();
            Chunk::new(envs, c as u32)
        })
        .collect();
    let mut pool = ChunkedThreadPool::spawn(16, chunks, states.clone(), chunk_size, 1, false);
    assert_eq!(pool.num_threads(), 3, "16 requested workers over 3 chunks");
    assert_eq!(pool.num_chunks(), 3);
    pool.schedule_reset_all();
    let mut out = states.make_output();
    states.recv_into(&mut out).unwrap();
    assert_eq!(out.len(), n);
    for _ in 0..20 {
        let ids = out.env_ids.clone();
        pool.send_actions(&vec![1.0f32; n], &ids);
        states.recv_into(&mut out).unwrap();
        assert!(out.obs.iter().all(|x| x.is_finite()));
    }
    pool.shutdown();
}

#[test]
fn vectorized_pool_with_fewer_envs_than_threads_round_trips() {
    // End-to-end flavor of the clamp: num_envs < num_threads must build
    // a working pool (one chunk per env, no empty chunks) and serve
    // every env.
    let cfg = PoolConfig::new("CartPole-v1")
        .num_envs(3)
        .batch_size(3)
        .num_threads(8)
        .seed(5)
        .exec_mode(ExecMode::Vectorized);
    let mut pool = EnvPool::make(cfg).unwrap();
    let mut out = pool.make_output();
    pool.reset_into(&mut out).unwrap();
    assert_eq!(out.len(), 3);
    let mut ids: Vec<u32> = out.env_ids.clone();
    ids.sort();
    assert_eq!(ids, vec![0, 1, 2]);
    for step in 0..40 {
        let ids = out.env_ids.clone();
        let actions: Vec<f32> = ids.iter().map(|&i| ((step + i as usize) % 2) as f32).collect();
        pool.step_into(&actions, &ids, &mut out).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.obs.iter().all(|x| x.is_finite()));
    }
    assert_eq!(pool.total_steps(), 40 * 3);
}

#[test]
fn zero_envs_is_a_config_error_not_a_panic() {
    for mode in [ExecMode::Scalar, ExecMode::Vectorized] {
        match EnvPool::make(PoolConfig::new("CartPole-v1").num_envs(0).exec_mode(mode)) {
            Err(Error::Config(msg)) => assert!(msg.contains("num_envs"), "{msg}"),
            other => panic!("{mode:?}: expected Config error, got {:?}", other.map(|_| ())),
        }
    }
    // The vectorized kernel layer rejects zero-lane batches directly too.
    assert!(matches!(
        registry::make_vec_env("CartPole-v1", 0, 0, 0),
        Err(Error::Config(_))
    ));
}

#[test]
fn state_queue_two_phase_writes_with_concurrent_consumer() {
    // The slot_obs_mut/commit path used by the chunked workers: a worker
    // fills a whole burst of K slots before committing any, while the
    // consumer drains concurrently. (A single writer keeps uncommitted
    // slots at the ring's head, mirroring the pool protocol's bound on
    // outstanding work — unbounded multi-writer pipelining is forbidden
    // there for exactly the liveness reasons a stress test would hit.)
    let k = 4; // slots acquired per burst
    let bursts = 2_000u32;
    let q = Arc::new(StateBufferQueue::new(2 * k, k, 8));
    let writer = {
        let q = q.clone();
        std::thread::spawn(move || {
            for b in 0..bursts {
                let tickets: Vec<_> = (0..k).map(|_| q.acquire().unwrap()).collect();
                for (j, &t) in tickets.iter().enumerate() {
                    let tag = b * k as u32 + j as u32;
                    // Safety: fresh tickets, one writer per slot.
                    unsafe { q.slot_obs_mut(t) }.fill(tag as f32);
                }
                // Commit in reverse order: completion counting must not
                // depend on commit order within a block.
                for (j, &t) in tickets.iter().enumerate().rev() {
                    let tag = b * k as u32 + j as u32;
                    q.commit(t, tag, tag as f32, false, false);
                }
            }
        })
    };
    let mut out = q.make_output();
    let mut expect = 0u32;
    for _ in 0..bursts {
        q.recv_into(&mut out).unwrap();
        for i in 0..out.len() {
            let tag = out.env_ids[i];
            assert_eq!(tag, expect, "rows out of order");
            expect += 1;
            assert!(out.obs_row(i).iter().all(|&x| x == tag as f32), "torn row {tag}");
            assert_eq!(out.rew[i], tag as f32);
        }
    }
    writer.join().unwrap();
}

#[test]
fn two_phase_commit_handles_atari_sized_rows_concurrently() {
    // The vectorized Atari/MuJoCo path pushes much larger observation
    // rows (4*84*84 floats) through slot_obs_mut/commit than the classic
    // kernels do. Concurrent writers filling whole frames into block
    // memory must never produce a torn row at the consumer.
    let obs_dim = 4 * 84 * 84;
    let per_writer = 50u32;
    let q = Arc::new(StateBufferQueue::new(8, 4, obs_dim));
    let writers: Vec<_> = (0..4u32)
        .map(|w| {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..per_writer {
                    let t = q.acquire().unwrap();
                    let tag = w * 1000 + i;
                    // Safety: fresh ticket, committed exactly once below.
                    unsafe { q.slot_obs_mut(t) }.fill(tag as f32);
                    q.commit(t, tag, tag as f32, false, false);
                }
            })
        })
        .collect();
    let mut out = q.make_output();
    let mut rows = 0usize;
    let batches = 4 * per_writer as usize / 4; // total rows / batch_size
    for _ in 0..batches {
        q.recv_into(&mut out).unwrap();
        for i in 0..out.len() {
            let tag = out.env_ids[i] as f32;
            assert_eq!(out.obs_row(i).len(), obs_dim);
            assert!(out.obs_row(i).iter().all(|&x| x == tag), "torn large row {tag}");
            rows += 1;
        }
    }
    assert_eq!(rows, 200);
    for w in writers {
        w.join().unwrap();
    }
}

/// Poll a join handle instead of joining outright so a regression (the
/// pre-fix behaviour was an infinite spin) fails the test instead of
/// hanging the whole suite.
fn join_within(h: std::thread::JoinHandle<()>, secs: u64, what: &str) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(secs);
    while !h.is_finished() {
        assert!(std::time::Instant::now() < deadline, "{what} did not finish within {secs}s");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    h.join().unwrap();
}

#[test]
fn dropping_pool_with_inflight_slots_does_not_hang() {
    // Regression (shutdown satellite): closing/dropping an async pool
    // while workers hold in-flight slots used to leave them spinning in
    // `StateBufferQueue::acquire` forever, so `close()`'s join never
    // returned. The queue's shutdown flag must let every worker bail.
    for mode in [ExecMode::Scalar, ExecMode::Vectorized] {
        let h = std::thread::spawn(move || {
            let mut pool = EnvPool::make(
                PoolConfig::new("CartPole-v1")
                    .num_envs(6)
                    .batch_size(2)
                    .num_threads(2)
                    .seed(17)
                    .exec_mode(mode),
            )
            .unwrap();
            pool.async_reset();
            let mut out = pool.make_output();
            // Take one batch and answer it so work is genuinely in flight,
            // then drop the pool without draining the rest.
            pool.recv_into(&mut out).unwrap();
            pool.send(&vec![0.0f32; out.len()], &out.env_ids.clone()).unwrap();
            drop(pool);
        });
        join_within(h, 30, "pool drop with in-flight slots");
    }
}

#[test]
fn recv_errors_instead_of_hanging_when_writer_panics() {
    // Regression (shutdown satellite): a writer that panics mid-round
    // leaves the round's block permanently incomplete; `recv` used to
    // spin on the `written` counter forever. The poison guard must close
    // the queue so the blocked consumer gets `Error::Closed`.
    let q = Arc::new(StateBufferQueue::new(4, 2, 8));
    let consumer = {
        let q = q.clone();
        std::thread::spawn(move || {
            let mut out = q.make_output();
            assert!(
                matches!(q.recv_into(&mut out), Err(Error::Closed)),
                "recv after writer panic must error, not hang"
            );
        })
    };
    let writer = {
        let q = q.clone();
        std::thread::spawn(move || {
            let _poison = q.poison_guard();
            let t = q.acquire().unwrap();
            q.write(t, 0, 0.0, false, false, |obs| obs.fill(1.0));
            // second slot of the batch never arrives
            panic!("simulated env crash");
        })
    };
    assert!(writer.join().is_err(), "writer thread must have panicked");
    join_within(consumer, 30, "consumer blocked on poisoned queue");
    assert!(q.acquire().is_none(), "poisoned queue must refuse new slots");
}
