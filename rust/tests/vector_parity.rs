//! Property tests pinning the vectorized (SoA) backends to the scalar
//! reference: for the same `(seed, env_id)` the two paths must produce
//! **bitwise-identical** trajectories — rewards, flags, and observations
//! — across all four classic-control tasks, through both the bare
//! executors and the pool engines.

use envpool::coordinator::throughput::random_actions;
use envpool::executors::{ForLoopExecutor, VecForLoopExecutor, VectorEnv};
use envpool::pool::{EnvPool, ExecMode, PoolConfig};
use envpool::prop::forall;
use envpool::prop_assert;
use envpool::rng::Pcg32;

const CLASSIC: &[&str] = &["CartPole-v1", "MountainCar-v0", "Pendulum-v1", "Acrobot-v1"];

/// Run scalar and vectorized for-loop executors lock-step on the same
/// random action stream and demand bitwise-equal streams (rewards,
/// flags, observations). For classic control and Atari this holds at
/// every lane width; for the walker family the bitwise contract is
/// **width 1** (the lane-grouped solver at widths > 1 follows the
/// documented tolerance budget in `tests/mujoco_batch_parity.rs`), so
/// walker callers pin `LanePass::Scalar` explicitly.
fn check_forloop_parity_lanes(
    task: &str,
    n: usize,
    seed: u64,
    steps: usize,
    lane_pass: envpool::simd::LanePass,
) {
    let mut a = ForLoopExecutor::new(task, n, seed).unwrap();
    let mut b = VecForLoopExecutor::new_with_lanes(task, n, seed, lane_pass).unwrap();
    let space = a.spec().action_space.clone();
    let mut oa = a.make_output();
    let mut ob = b.make_output();
    a.reset(&mut oa).unwrap();
    b.reset(&mut ob).unwrap();
    assert_eq!(oa.obs, ob.obs, "{task}: reset obs diverge");
    let mut arng = Pcg32::new(seed ^ 0xF00D, 2);
    let mut actions = Vec::new();
    for s in 0..steps {
        random_actions(&space, n, &mut arng, &mut actions);
        a.step(&actions, &mut oa).unwrap();
        b.step(&actions, &mut ob).unwrap();
        assert_eq!(oa.rew, ob.rew, "{task}: rewards diverge at step {s}");
        assert_eq!(oa.done, ob.done, "{task}: dones diverge at step {s}");
        assert_eq!(oa.trunc, ob.trunc, "{task}: truncs diverge at step {s}");
        assert_eq!(oa.obs, ob.obs, "{task}: obs diverge at step {s}");
    }
}

#[test]
fn walker_family_vec_kernels_bitwise_identical_to_scalar_at_width1() {
    // MuJoCo walkers + the dm_control task over them: at lane width 1
    // the batch-resident kernel must reproduce the scalar envs exactly,
    // including episode terminations and auto-resets along the way.
    // (Widths > 1 run the lane-grouped solver under the documented
    // tolerance contract — tests/mujoco_batch_parity.rs.)
    for task in ["Hopper-v4", "HalfCheetah-v4", "Ant-v4", "cheetah_run"] {
        check_forloop_parity_lanes(task, 2, 5, 100, envpool::simd::LanePass::Scalar);
    }
}

#[test]
fn atari_vec_kernels_bitwise_identical_to_scalar() {
    // Batched emulator lanes + shared preprocessing: bitwise parity on
    // the full (4, 84, 84) observation tensors. The emulator itself now
    // runs as masked lane-group tick passes, but its contract is bitwise
    // at *every* width (selects apply the identical scalar ops per lane),
    // so Auto is fine here; `tests/atari_emulate_parity.rs` pins each
    // width explicitly.
    for task in ["Pong-v5", "Breakout-v5"] {
        check_forloop_parity_lanes(task, 2, 9, 30, envpool::simd::LanePass::Auto);
    }
}

#[test]
fn pool_exec_modes_bitwise_identical_for_walker_and_atari() {
    // The same contract through the full pool engines (threads, chunked
    // dispatch, state-queue commits) for the non-classic families. The
    // walker's bitwise contract is width 1, so the pool's lane pass is
    // pinned to Scalar (the scalar engine is width-1 by construction).
    for task in ["Hopper-v4", "Pong-v5"] {
        let run = |mode: ExecMode| -> (Vec<f32>, Vec<f32>, Vec<u8>) {
            let pool = EnvPool::make(
                PoolConfig::new(task)
                    .num_envs(4)
                    .batch_size(4)
                    .num_threads(2)
                    .seed(23)
                    .exec_mode(mode)
                    .lane_pass(envpool::simd::LanePass::Scalar),
            )
            .unwrap();
            let mut ex = envpool::executors::PoolVectorEnv::new(pool).unwrap();
            let mut out = ex.make_output();
            ex.reset(&mut out).unwrap();
            let space = ex.spec().action_space.clone();
            let mut arng = Pcg32::new(23, 4);
            let mut actions = Vec::new();
            let (mut obs, mut rew, mut done) = (Vec::new(), Vec::new(), Vec::new());
            obs.extend_from_slice(&out.obs);
            for _ in 0..20 {
                random_actions(&space, 4, &mut arng, &mut actions);
                ex.step(&actions, &mut out).unwrap();
                obs.extend_from_slice(&out.obs);
                rew.extend_from_slice(&out.rew);
                done.extend_from_slice(&out.done);
            }
            (obs, rew, done)
        };
        let scalar = run(ExecMode::Scalar);
        let vector = run(ExecMode::Vectorized);
        assert_eq!(scalar.1, vector.1, "{task}: pool rewards diverge");
        assert_eq!(scalar.2, vector.2, "{task}: pool dones diverge");
        assert_eq!(scalar.0, vector.0, "{task}: pool obs diverge");
    }
}

#[test]
fn prop_vector_and_scalar_backends_bitwise_identical() {
    forall("vector-scalar-parity", |g| {
        let task = *g.choose(CLASSIC);
        let n = g.usize_in(1, 6);
        let seed = g.usize_in(0, 10_000) as u64;
        let mut a = ForLoopExecutor::new(task, n, seed).map_err(|e| e.to_string())?;
        let mut b = VecForLoopExecutor::new(task, n, seed).map_err(|e| e.to_string())?;
        let space = a.spec().action_space.clone();
        let mut oa = a.make_output();
        let mut ob = b.make_output();
        a.reset(&mut oa).map_err(|e| e.to_string())?;
        b.reset(&mut ob).map_err(|e| e.to_string())?;
        prop_assert!(oa.obs == ob.obs, "{task}: reset obs diverge");

        // Random valid actions; auto-resets happen inside the 100 steps
        // for the short-episode tasks, exercising the mask path.
        let mut arng = Pcg32::new(seed ^ 0xAC7104, 7);
        let mut actions = Vec::new();
        for s in 0..100 {
            random_actions(&space, n, &mut arng, &mut actions);
            a.step(&actions, &mut oa).map_err(|e| e.to_string())?;
            b.step(&actions, &mut ob).map_err(|e| e.to_string())?;
            prop_assert!(oa.rew == ob.rew, "{task}: rewards diverge at step {s}");
            prop_assert!(oa.done == ob.done, "{task}: dones diverge at step {s}");
            prop_assert!(oa.trunc == ob.trunc, "{task}: truncs diverge at step {s}");
            prop_assert!(oa.obs == ob.obs, "{task}: obs diverge at step {s}");
        }
        Ok(())
    });
}

#[test]
fn prop_pool_exec_modes_bitwise_identical_in_sync_mode() {
    // The same property through the full pool: scalar per-env tasks vs
    // chunked SoA workers, arbitrary thread counts.
    forall("pool-exec-mode-parity", |g| {
        let task = *g.choose(CLASSIC);
        let n = g.usize_in(1, 6);
        let threads = g.usize_in(1, 3);
        let seed = g.usize_in(0, 10_000) as u64;
        let steps = g.usize_in(10, 60);

        let run = |mode: ExecMode| -> Result<(Vec<f32>, Vec<f32>, Vec<u8>), String> {
            let pool = EnvPool::make(
                PoolConfig::new(task)
                    .num_envs(n)
                    .batch_size(n)
                    .num_threads(threads)
                    .seed(seed)
                    .exec_mode(mode),
            )
            .map_err(|e| e.to_string())?;
            let mut ex =
                envpool::executors::PoolVectorEnv::new(pool).map_err(|e| e.to_string())?;
            let mut out = ex.make_output();
            ex.reset(&mut out).map_err(|e| e.to_string())?;
            let space = ex.spec().action_space.clone();
            let mut arng = Pcg32::new(seed ^ 0x9001, 3);
            let mut actions = Vec::new();
            let (mut obs, mut rew, mut done) = (Vec::new(), Vec::new(), Vec::new());
            obs.extend_from_slice(&out.obs);
            for _ in 0..steps {
                random_actions(&space, n, &mut arng, &mut actions);
                ex.step(&actions, &mut out).map_err(|e| e.to_string())?;
                obs.extend_from_slice(&out.obs);
                rew.extend_from_slice(&out.rew);
                done.extend_from_slice(&out.done);
            }
            Ok((obs, rew, done))
        };

        let scalar = run(ExecMode::Scalar)?;
        let vector = run(ExecMode::Vectorized)?;
        prop_assert!(scalar.1 == vector.1, "{task}: pool rewards diverge");
        prop_assert!(scalar.2 == vector.2, "{task}: pool dones diverge");
        prop_assert!(scalar.0 == vector.0, "{task}: pool obs diverge");
        Ok(())
    });
}

#[test]
fn prop_async_vectorized_pool_routes_correctly() {
    // The routing/serving invariants of the async pool hold under the
    // chunked engine too: batches are exactly M rows, ids are in range,
    // and only envs with an action in flight ever report a result.
    forall("async-vectorized-routing", |g| {
        let task = *g.choose(CLASSIC);
        let n = g.usize_in(2, 10);
        let threads = g.usize_in(1, 3);
        // Respect the chunked engine's liveness constraint: async batch
        // sizes must not exceed the chunk count (sync M == N is exempt).
        let chunk_size = n.div_ceil(threads);
        let num_chunks = n.div_ceil(chunk_size);
        let m = if g.bool() { n } else { g.usize_in(1, num_chunks) };
        let mut pool = EnvPool::make(
            PoolConfig::new(task)
                .num_envs(n)
                .batch_size(m)
                .num_threads(threads)
                .seed(5)
                .exec_mode(ExecMode::Vectorized),
        )
        .map_err(|e| e.to_string())?;
        pool.async_reset();
        let space = pool.spec().action_space.clone();
        let mut out = pool.make_output();
        let mut outstanding = vec![1u32; n];
        let mut arng = Pcg32::new(77, 1);
        let mut actions = Vec::new();
        for _ in 0..30 {
            pool.recv_into(&mut out).unwrap();
            prop_assert!(out.len() == m, "batch size {} != {m}", out.len());
            for &id in &out.env_ids {
                prop_assert!((id as usize) < n, "env id {id} out of range");
                prop_assert!(outstanding[id as usize] > 0, "result for idle env {id}");
                outstanding[id as usize] -= 1;
            }
            random_actions(&space, m, &mut arng, &mut actions);
            pool.send(&actions, &out.env_ids.clone()).map_err(|e| e.to_string())?;
            for &id in &out.env_ids {
                outstanding[id as usize] += 1;
            }
        }
        Ok(())
    });
}
