//! Parity suite for the batched Atari emulator (`envs::vector::atari_emulate`).
//!
//! The lane-group tick passes promise **bitwise identity** with the
//! scalar `Game::tick` reference at every lane width — branches become
//! masked selects that apply the identical scalar operation per lane,
//! RNG draws stay scalar per lane in lane order, and f32 expressions
//! keep the exact scalar operation order. This file pins that promise
//! end to end, on full `(4, 84, 84)` observation tensors:
//!
//! - widths 1/4/8 against per-env scalar references, random actions;
//! - forced mid-batch resets rotating through the lanes at each width;
//! - episodic-life Breakout under the pool's auto-reset protocol
//!   (life-loss `done` with the game not over → continuation reset);
//! - both `ExecMode`s through the full pool engines.

use envpool::coordinator::throughput::random_actions;
use envpool::envs::atari::preproc;
use envpool::envs::vector::atari::{breakout_vec, pong_vec};
use envpool::envs::vector::{AtariVec, LaneGame};
use envpool::envs::{Env, SliceArena, Step, VecEnv};
use envpool::executors::{ForLoopExecutor, VecForLoopExecutor, VectorEnv};
use envpool::pool::{EnvPool, ExecMode, PoolConfig};
use envpool::rng::Pcg32;
use envpool::simd::LanePass;

const WIDTHS: [LanePass; 3] = [LanePass::Scalar, LanePass::Width4, LanePass::Width8];

/// Scalar vs vectorized for-loop executors, lock-step on one random
/// action stream, full-tensor bitwise compare each step.
fn check_executor_parity(task: &str, n: usize, seed: u64, steps: usize, lp: LanePass) {
    let mut a = ForLoopExecutor::new(task, n, seed).unwrap();
    let mut b = VecForLoopExecutor::new_with_lanes(task, n, seed, lp).unwrap();
    let space = a.spec().action_space.clone();
    let mut oa = a.make_output();
    let mut ob = b.make_output();
    a.reset(&mut oa).unwrap();
    b.reset(&mut ob).unwrap();
    assert!(oa.obs == ob.obs, "{task} {lp:?}: reset obs diverge");
    let mut arng = Pcg32::new(seed ^ 0xA7A21, 3);
    let mut actions = Vec::new();
    for s in 0..steps {
        random_actions(&space, n, &mut arng, &mut actions);
        a.step(&actions, &mut oa).unwrap();
        b.step(&actions, &mut ob).unwrap();
        assert_eq!(oa.rew, ob.rew, "{task} {lp:?}: rewards diverge at step {s}");
        assert_eq!(oa.done, ob.done, "{task} {lp:?}: dones diverge at step {s}");
        assert!(oa.obs == ob.obs, "{task} {lp:?}: obs diverge at step {s}");
    }
}

#[test]
fn executors_bitwise_at_widths_1_4_8_random_actions() {
    for task in ["Pong-v5", "Breakout-v5"] {
        for lp in WIDTHS {
            check_executor_parity(task, 5, 31, 25, lp);
        }
    }
}

/// Drive an [`AtariVec`] and a row of scalar reference envs through the
/// same action tape with a reset mask rotating through the lanes, at
/// one lane width. `mask_from_done` switches from forced rotation to
/// the pool's auto-reset protocol (reset exactly the lanes whose
/// previous transition finished).
fn check_masked_parity<L: LaneGame, E: Env>(
    mut v: AtariVec<L>,
    mut scalars: Vec<E>,
    n_act: u32,
    steps: usize,
    mask_from_done: bool,
    tag: &str,
) -> usize {
    let n = scalars.len();
    let dim = v.spec().obs_dim();
    let mut vobs = vec![0.0f32; n * dim];
    let mut sobs = vec![0.0f32; dim];
    for (l, env) in scalars.iter_mut().enumerate() {
        v.reset_lane(l, &mut vobs[l * dim..(l + 1) * dim]);
        env.reset(&mut sobs);
        assert!(vobs[l * dim..(l + 1) * dim] == sobs[..], "{tag}: reset lane {l}");
    }
    let mut arng = Pcg32::new(0x5EED ^ n_act as u64, 9);
    let mut results = vec![Step::default(); n];
    let mut mask = vec![0u8; n];
    let mut dones = 0usize;
    for t in 0..steps {
        if !mask_from_done {
            mask.iter_mut().for_each(|m| *m = 0);
            if t % 3 == 2 {
                mask[t % n] = 1; // forced mid-batch reset
            }
        }
        let actions: Vec<f32> = (0..n).map(|_| arng.below(n_act) as f32).collect();
        {
            let mut arena = SliceArena::new(&mut vobs, dim);
            v.step_batch(&actions, &mask, &mut arena, &mut results);
        }
        for (l, env) in scalars.iter_mut().enumerate() {
            if mask[l] != 0 {
                env.reset(&mut sobs);
                assert_eq!(results[l], Step::default(), "{tag}: reset step {t} lane {l}");
            } else {
                let s = env.step(&actions[l..l + 1], &mut sobs);
                assert_eq!(results[l], s, "{tag}: step {t} lane {l}");
                dones += s.done as usize;
            }
            assert!(vobs[l * dim..(l + 1) * dim] == sobs[..], "{tag}: obs {t} lane {l}");
        }
        if mask_from_done {
            for l in 0..n {
                mask[l] = results[l].finished() as u8;
            }
        }
    }
    dones
}

#[test]
fn forced_midbatch_resets_bitwise_at_widths_1_4_8() {
    for lp in WIDTHS {
        let mut v = pong_vec(14, 0, 3);
        v.set_lane_pass(lp);
        let scalars: Vec<_> = (0..3).map(|i| preproc::pong(14, i)).collect();
        check_masked_parity(v, scalars, 6, 20, false, &format!("pong {lp:?}"));

        let mut v = breakout_vec(14, 0, 3);
        v.set_lane_pass(lp);
        let scalars: Vec<_> = (0..3).map(|i| preproc::breakout(14, i)).collect();
        check_masked_parity(v, scalars, 4, 20, false, &format!("breakout {lp:?}"));
    }
}

#[test]
fn episodic_life_breakout_auto_resets_bitwise() {
    // Breakout runs with episodic life: losing a ball reports `done`
    // while the game is not over, and the following reset is a
    // *continuation* (no full game reset, the brick wall survives).
    // Under the pool's auto-reset protocol the batched path must track
    // the scalar wrapper through those continuation resets bit for bit.
    // Long horizon so lives are actually lost; run the wider passes
    // (width 1 is pinned by the other tests).
    for lp in [LanePass::Width4, LanePass::Width8] {
        let mut v = breakout_vec(8, 0, 2);
        v.set_lane_pass(lp);
        let scalars: Vec<_> = (0..2).map(|i| preproc::breakout(8, i)).collect();
        let dones =
            check_masked_parity(v, scalars, 4, 1500, true, &format!("ep-life {lp:?}"));
        assert!(dones > 0, "{lp:?}: horizon too short — no life was ever lost");
    }
}

#[test]
fn pool_exec_modes_bitwise_for_pong_and_breakout() {
    // Scalar pool engine (per-env tasks over width-1 views) vs the
    // chunked vectorized engine running the batched emulator at Auto
    // width: rewards, dones and full observation streams bit for bit.
    for task in ["Pong-v5", "Breakout-v5"] {
        let run = |mode: ExecMode| -> (Vec<f32>, Vec<f32>, Vec<u8>) {
            let pool = EnvPool::make(
                PoolConfig::new(task)
                    .num_envs(4)
                    .batch_size(4)
                    .num_threads(2)
                    .seed(19)
                    .exec_mode(mode)
                    .lane_pass(LanePass::Auto),
            )
            .unwrap();
            let mut ex = envpool::executors::PoolVectorEnv::new(pool).unwrap();
            let mut out = ex.make_output();
            ex.reset(&mut out).unwrap();
            let space = ex.spec().action_space.clone();
            let mut arng = Pcg32::new(19, 6);
            let mut actions = Vec::new();
            let (mut obs, mut rew, mut done) = (Vec::new(), Vec::new(), Vec::new());
            obs.extend_from_slice(&out.obs);
            for _ in 0..15 {
                random_actions(&space, 4, &mut arng, &mut actions);
                ex.step(&actions, &mut out).unwrap();
                obs.extend_from_slice(&out.obs);
                rew.extend_from_slice(&out.rew);
                done.extend_from_slice(&out.done);
            }
            (obs, rew, done)
        };
        let scalar = run(ExecMode::Scalar);
        let vector = run(ExecMode::Vectorized);
        assert_eq!(scalar.1, vector.1, "{task}: pool rewards diverge");
        assert_eq!(scalar.2, vector.2, "{task}: pool dones diverge");
        assert!(scalar.0 == vector.0, "{task}: pool obs diverge");
    }
}
