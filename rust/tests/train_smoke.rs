//! Integration: the full stack trains end to end.
//!
//! Since the native compute backend (`--backend native`) exists, the
//! trainer path runs **for real** in every checkout — no PJRT, no
//! artifacts needed — so these tests execute instead of skipping. Only
//! the PJRT-specific artifact-parity test still skips when the compute
//! tier is the vendored stub (`compute_or_skip!`).

use envpool::compute_or_skip;
use envpool::config::{BackendKind, ExecutorKind, Precision, TrainConfig};
use envpool::coordinator::ppo;
use envpool::runtime::{Manifest, Policy, Runtime};

fn set_worker_bin() {
    // CARGO_BIN_EXE_* is provided to integration tests at compile time.
    std::env::set_var("ENVPOOL_WORKER_BIN", env!("CARGO_BIN_EXE_envpool"));
}

fn native_cfg(env: &str, executor: ExecutorKind, steps: u64) -> TrainConfig {
    TrainConfig {
        env_id: env.into(),
        executor,
        backend: BackendKind::Native,
        num_envs: 8,
        batch_size: 8,
        num_threads: 2,
        num_steps: 64,
        total_steps: steps,
        ..TrainConfig::default()
    }
}

#[test]
fn subprocess_executor_trains() {
    set_worker_bin();
    let cfg = native_cfg("CartPole-v1", ExecutorKind::Subprocess, 1024);
    let s = ppo::train(&cfg).unwrap();
    assert_eq!(s.backend, "native");
    assert_eq!(s.env_steps, 1024);
    assert!(s.episodes > 0);
}

#[test]
fn vectorized_pool_executor_trains_identically_to_scalar() {
    // ExecMode is an execution detail: training through the chunked SoA
    // backend must reproduce the scalar pool's run exactly.
    let a = ppo::train(&native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 1024)).unwrap();
    let b = ppo::train(&native_cfg("CartPole-v1", ExecutorKind::EnvPoolSyncVec, 1024)).unwrap();
    assert_eq!(a.episodes, b.episodes);
    assert_eq!(a.final_return, b.final_return);
}

#[test]
fn native_training_is_deterministic() {
    // Pcg32-seeded init + sampling + f64 math: the same config must
    // reproduce the same run bit for bit.
    let mk = || native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 4 * 8 * 64);
    let a = ppo::train(&mk()).unwrap();
    let b = ppo::train(&mk()).unwrap();
    assert_eq!(a.episodes, b.episodes);
    assert_eq!(a.final_return, b.final_return);
    assert_eq!(a.best_return, b.best_return);
}

#[test]
fn f32_precision_trains_and_reruns_bit_exactly() {
    // The f32 fast path end to end: `--precision f32` must train, be
    // exactly rerun-deterministic (same config → identical summary),
    // and report its precision in the summary.
    let mk = || {
        let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSyncVec, 4 * 8 * 64);
        cfg.precision = Precision::F32;
        cfg
    };
    let a = ppo::train(&mk()).unwrap();
    let b = ppo::train(&mk()).unwrap();
    assert_eq!(a.backend, "native");
    assert_eq!(a.precision, "f32");
    assert!(a.final_return.is_finite());
    assert!(a.episodes > 0);
    assert_eq!(a.episodes, b.episodes);
    assert_eq!(a.final_return, b.final_return);
    assert_eq!(a.best_return, b.best_return);
    // f64 runs report the reference precision
    let c = ppo::train(&native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 1024)).unwrap();
    assert_eq!(c.precision, "f64");
}

#[test]
fn f32_and_f64_learning_signals_stay_comparable() {
    // The fast path is an *approximation*: trajectories diverge from
    // f64 over time (sampling reads f32 logits), so exact equality is
    // wrong to demand — but after identical short training both must
    // produce finite, sane returns from real episodes.
    let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSyncVec, 2 * 8 * 64);
    cfg.precision = Precision::F32;
    let s32 = ppo::train(&cfg).unwrap();
    cfg.precision = Precision::F64;
    let s64 = ppo::train(&cfg).unwrap();
    for s in [&s32, &s64] {
        assert_eq!(s.iterations, 2);
        assert!(s.episodes > 0);
        assert!(s.final_return.is_finite() && s.final_return > 0.0);
    }
}

#[test]
fn eval_episodes_runs_greedy_eval_on_the_trained_backend() {
    let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 1024);
    cfg.eval_episodes = 4;
    let s = ppo::train(&cfg).unwrap();
    let r = s.eval_return.expect("eval_return must be set when eval_episodes > 0");
    assert!((1.0..=500.0).contains(&r), "greedy CartPole return {r}");
    assert!(s.render().contains("eval return"), "summary must surface it:\n{}", s.render());
    // off by default
    let s = ppo::train(&native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 1024)).unwrap();
    assert!(s.eval_return.is_none());
}

#[test]
fn forced_lane_widths_train_identically() {
    // TrainConfig::lane_pass reaches the vectorized pool engine; every
    // width must produce the identical run (bitwise kernels).
    use envpool::simd::LanePass;
    let run = |lp: LanePass| {
        let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSyncVec, 2 * 8 * 64);
        cfg.lane_pass = lp;
        ppo::train(&cfg).unwrap()
    };
    let base = run(LanePass::Scalar);
    for lp in [LanePass::Width4, LanePass::Width8] {
        let s = run(lp);
        assert_eq!(s.episodes, base.episodes, "{lp}");
        assert_eq!(s.final_return, base.final_return, "{lp}");
    }
}

#[test]
fn continuous_pendulum_trains_natively() {
    let mut cfg = native_cfg("Pendulum-v1", ExecutorKind::EnvPoolSync, 2 * 8 * 64);
    cfg.seed = 2;
    let s = ppo::train(&cfg).unwrap();
    assert_eq!(s.iterations, 2);
    assert!(s.final_return.is_finite());
}

#[test]
fn default_auto_backend_trains_with_whatever_tier_is_present() {
    // Keeps the PJRT train path covered where it exists: with the default
    // artifacts dir, `auto` resolves to pjrt in artifact-equipped
    // checkouts (exercising PjrtBackend through the full trainer loop)
    // and to native under the vendored stub — either way the run must
    // complete and say which tier it used.
    // (num_steps only binds the native schedule — PjrtBackend takes its
    // rollout shape from the artifact manifest.)
    let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 1024);
    cfg.backend = BackendKind::Auto;
    let s = ppo::train(&cfg).unwrap();
    assert!(s.backend == "pjrt" || s.backend == "native", "unknown backend {}", s.backend);
    assert!(s.env_steps > 0);
    assert!(s.final_return.is_finite());
}

#[test]
fn auto_backend_falls_back_to_native_without_artifacts() {
    let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 1024);
    cfg.backend = BackendKind::Auto;
    cfg.artifacts_dir = "definitely-not-an-artifacts-dir".into();
    let s = ppo::train(&cfg).unwrap();
    assert_eq!(s.backend, "native", "auto must fall back when PJRT is unavailable");
}

#[test]
fn explicit_pjrt_backend_surfaces_missing_compute_tier() {
    let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 1024);
    cfg.backend = BackendKind::Pjrt;
    cfg.artifacts_dir = "definitely-not-an-artifacts-dir".into();
    assert!(ppo::train(&cfg).is_err(), "--backend pjrt must not silently fall back");
}

#[test]
fn pallas_artifact_policy_matches_jnp_artifact() {
    // The same parameters through the jnp-lowered and Pallas-lowered
    // policies must produce identical numbers (kernel parity, via PJRT).
    let rt = compute_or_skip!(Runtime::cpu());
    let m = compute_or_skip!(Manifest::load("artifacts"));
    let a = m.by_key("cartpole_n8").unwrap();
    let b = m.by_key("cartpole_n8_pallas").unwrap();
    let params = envpool::agent::ParamStore::load(&m, a).unwrap();
    let pa = Policy::load(&rt, a).unwrap();
    let pb = Policy::load(&rt, b).unwrap();
    let obs: Vec<f32> = (0..8 * 4).map(|i| (i as f32 * 0.37).sin() * 0.3).collect();
    let oa = pa.forward(&rt, &params, &obs).unwrap();
    let ob = pb.forward(&rt, &params, &obs).unwrap();
    for (x, y) in oa.dist.iter().zip(&ob.dist) {
        assert!((x - y).abs() < 2e-5, "pallas vs jnp logits: {x} vs {y}");
    }
    for (x, y) in oa.value.iter().zip(&ob.value) {
        assert!((x - y).abs() < 2e-5, "pallas vs jnp values: {x} vs {y}");
    }
}

#[test]
fn async_train_learns_comparably_to_sync() {
    // The decoupled loop is off-policy by a bounded amount, not a
    // different algorithm: over the same budget it must show a learning
    // signal comparable to the synchronous loop's (floors, not equality
    // — batch arrival order is timing-dependent), and the summary must
    // account for the staleness it actually incurred.
    let mut sync_cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 30 * 8 * 64);
    sync_cfg.learning_rate = 2.5e-3;
    sync_cfg.clip_coef = 0.2;
    sync_cfg.seed = 3;
    let mut async_cfg = sync_cfg.clone();
    async_cfg.executor = ExecutorKind::EnvPoolAsync;
    async_cfg.batch_size = 4;
    async_cfg.async_train = true;
    async_cfg.max_policy_lag = Some(4);

    let sync = ppo::train(&sync_cfg).unwrap();
    let s = ppo::train(&async_cfg).unwrap();
    assert_eq!(s.env_steps, sync.env_steps, "same step budget");
    assert!(s.episodes > 0);
    // learning floor: well above CartPole's ~20-25 random-policy return
    assert!(
        s.best_return > 45.0,
        "async loop shows no learning signal: best window {}",
        s.best_return
    );
    // lag is reported and respects the structural bound of one round of
    // updates (update_epochs × num_minibatches)
    let max = s.policy_lag_max.expect("async summary must report lag");
    let mean = s.policy_lag_mean.expect("async summary must report lag");
    let structural = (async_cfg.update_epochs * async_cfg.num_minibatches) as u32;
    assert!(max <= structural, "lag max {max} exceeds structural bound {structural}");
    assert!(mean >= 0.0 && mean <= structural as f32);
    assert!(s.render().contains("policy lag"), "{}", s.render());
}

#[test]
fn learning_signal_appears_quickly_on_cartpole() {
    // 40 iterations of PPO must lift the trailing mean return well above
    // the random-policy baseline (~20-25 for CartPole under PPO's inits).
    let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 40 * 8 * 64);
    cfg.learning_rate = 2.5e-3;
    cfg.clip_coef = 0.2;
    cfg.seed = 3;
    let s = ppo::train(&cfg).unwrap();
    let early = s.curve[1].mean_return;
    assert!(
        s.best_return > early * 1.5 && s.best_return > 45.0,
        "no learning signal: early {early}, best {}",
        s.best_return
    );
}

#[test]
fn native_backend_solves_cartpole() {
    // The acceptance smoke: a seeded native-backend run must reach a
    // trailing mean return of >= 475 (the gym "solved" bar) within a
    // bounded step budget. target_return stops the run as soon as the
    // bar is cleared, so the happy path costs a fraction of the budget.
    let mut cfg = native_cfg("CartPole-v1", ExecutorKind::EnvPoolSync, 0);
    cfg.num_steps = 128;
    cfg.total_steps = 400 * 8 * 128; // 409.6k-step budget at T=128
    cfg.learning_rate = 2.5e-3;
    cfg.clip_coef = 0.2;
    cfg.seed = 1;
    cfg.target_return = Some(475.0);
    let s = ppo::train(&cfg).unwrap();
    assert!(
        s.best_return >= 475.0,
        "native PPO must solve CartPole within {} steps; best window {} after {} iterations",
        cfg.total_steps,
        s.best_return,
        s.iterations
    );
}
