//! Integration: the full three-layer stack trains end to end — including
//! through the subprocess executor (real worker processes) and through
//! the Pallas-lowered artifact variant.
//!
//! The compute tier (PJRT runtime + AOT artifacts) is optional in this
//! checkout: the `xla` dependency may be the vendored stub and
//! `make artifacts` may not have run. Every test here skips cleanly in
//! that case — the pure-Rust tiers have their own suites.

use envpool::compute_or_skip;
use envpool::config::{ExecutorKind, TrainConfig};
use envpool::coordinator::ppo;
use envpool::runtime::{Manifest, Policy, Runtime};

fn set_worker_bin() {
    // CARGO_BIN_EXE_* is provided to integration tests at compile time.
    std::env::set_var("ENVPOOL_WORKER_BIN", env!("CARGO_BIN_EXE_envpool"));
}

#[test]
fn subprocess_executor_trains() {
    set_worker_bin();
    let cfg = TrainConfig {
        env_id: "CartPole-v1".into(),
        executor: ExecutorKind::Subprocess,
        num_envs: 8,
        batch_size: 8,
        total_steps: 1024,
        ..TrainConfig::default()
    };
    let s = compute_or_skip!(ppo::train(&cfg));
    assert_eq!(s.env_steps, 1024);
    assert!(s.episodes > 0);
}

#[test]
fn vectorized_pool_executor_trains_identically_to_scalar() {
    // ExecMode is an execution detail: training through the chunked SoA
    // backend must reproduce the scalar pool's run exactly.
    let mk = |executor: ExecutorKind| TrainConfig {
        env_id: "CartPole-v1".into(),
        executor,
        num_envs: 8,
        batch_size: 8,
        num_threads: 2,
        total_steps: 1024,
        ..TrainConfig::default()
    };
    let a = compute_or_skip!(ppo::train(&mk(ExecutorKind::EnvPoolSync)));
    let b = compute_or_skip!(ppo::train(&mk(ExecutorKind::EnvPoolSyncVec)));
    assert_eq!(a.episodes, b.episodes);
    assert_eq!(a.final_return, b.final_return);
}

#[test]
fn pallas_artifact_policy_matches_jnp_artifact() {
    // The same parameters through the jnp-lowered and Pallas-lowered
    // policies must produce identical numbers (kernel parity, via PJRT).
    let rt = compute_or_skip!(Runtime::cpu());
    let m = compute_or_skip!(Manifest::load("artifacts"));
    let a = m.by_key("cartpole_n8").unwrap();
    let b = m.by_key("cartpole_n8_pallas").unwrap();
    let params = envpool::agent::ParamStore::load(&m, a).unwrap();
    let pa = Policy::load(&rt, a).unwrap();
    let pb = Policy::load(&rt, b).unwrap();
    let obs: Vec<f32> = (0..8 * 4).map(|i| (i as f32 * 0.37).sin() * 0.3).collect();
    let oa = pa.forward(&rt, &params, &obs).unwrap();
    let ob = pb.forward(&rt, &params, &obs).unwrap();
    for (x, y) in oa.dist.iter().zip(&ob.dist) {
        assert!((x - y).abs() < 2e-5, "pallas vs jnp logits: {x} vs {y}");
    }
    for (x, y) in oa.value.iter().zip(&ob.value) {
        assert!((x - y).abs() < 2e-5, "pallas vs jnp values: {x} vs {y}");
    }
}

#[test]
fn learning_signal_appears_quickly_on_cartpole() {
    // 40 iterations of PPO must lift the trailing mean return well above
    // the random-policy baseline (~20-25 for CartPole under PPO's inits).
    let cfg = TrainConfig {
        env_id: "CartPole-v1".into(),
        executor: ExecutorKind::EnvPoolSync,
        num_envs: 8,
        batch_size: 8,
        num_threads: 2,
        total_steps: 40 * 8 * 128,
        learning_rate: 2.5e-3,
        seed: 3,
        ..TrainConfig::default()
    };
    let s = compute_or_skip!(ppo::train(&cfg));
    let early = s.curve[1].mean_return;
    assert!(
        s.best_return > early * 1.5 && s.best_return > 45.0,
        "no learning signal: early {early}, best {}",
        s.best_return
    );
}
