//! Wrapper-stack parity across execution modes: the same `WrapConfig`
//! must produce bitwise-identical transition streams whether the pool
//! runs per-env scalar workers (`ExecMode::Scalar`, one-lane wrapper
//! adapters) or chunked SoA workers (`ExecMode::Vectorized`, batch-wise
//! `VecWrapper`s). Also pins each wrapper's semantics: truncation vs
//! termination flags for `TimeLimit`, bounds for `RewardClip`, and
//! running-stat determinism for `NormalizeObs`.

use envpool::envs::WrapConfig;
use envpool::executors::{PoolVectorEnv, VectorEnv};
use envpool::pool::{EnvPool, ExecMode, PoolConfig};

/// Transition stream (env-id order) of a wrapped sync pool.
struct Stream {
    obs: Vec<f32>,
    rew: Vec<f32>,
    done: Vec<u8>,
    trunc: Vec<u8>,
}

/// Drive a wrapped sync-mode pool for `steps` steps with a deterministic
/// per-env action policy and record the full stream.
///
/// The lane pass is pinned to width 1: cross-mode *bitwise* equality is
/// a width-1 contract for the walker family (the lane-grouped solver at
/// widths > 1 follows the documented tolerance budget —
/// `tests/mujoco_batch_parity.rs`); for classic control every width is
/// bitwise anyway (`tests/simd_parity.rs`), so nothing is lost here.
fn run(task: &str, wrap: WrapConfig, mode: ExecMode, steps: usize, seed: u64) -> Stream {
    let pool = EnvPool::make(
        PoolConfig::new(task)
            .num_envs(4)
            .batch_size(4)
            .num_threads(2)
            .seed(seed)
            .exec_mode(mode)
            .wrappers(wrap)
            .lane_pass(envpool::simd::LanePass::Scalar),
    )
    .unwrap();
    let mut ex = PoolVectorEnv::new(pool).unwrap();
    let adim = ex.spec().action_space.dim();
    let discrete = ex.spec().action_space.is_discrete();
    let mut out = ex.make_output();
    ex.reset(&mut out).unwrap();
    let mut s = Stream { obs: Vec::new(), rew: Vec::new(), done: Vec::new(), trunc: Vec::new() };
    s.obs.extend_from_slice(&out.obs);
    for t in 0..steps {
        let actions: Vec<f32> = (0..4 * adim)
            .map(|k| {
                if discrete {
                    ((t + k) % 2) as f32
                } else {
                    ((t * 3 + k) % 7) as f32 / 3.5 - 1.0
                }
            })
            .collect();
        ex.step(&actions, &mut out).unwrap();
        s.obs.extend_from_slice(&out.obs);
        s.rew.extend_from_slice(&out.rew);
        s.done.extend_from_slice(&out.done);
        s.trunc.extend_from_slice(&out.trunc);
    }
    s
}

fn assert_streams_equal(a: &Stream, b: &Stream, what: &str) {
    assert_eq!(a.rew, b.rew, "{what}: rewards diverge across exec modes");
    assert_eq!(a.done, b.done, "{what}: done flags diverge across exec modes");
    assert_eq!(a.trunc, b.trunc, "{what}: truncated flags diverge across exec modes");
    assert_eq!(a.obs, b.obs, "{what}: observations diverge across exec modes");
}

#[test]
fn time_limit_truncation_flags_agree_across_modes() {
    // Pendulum never terminates, so a 5-step limit makes a pure
    // truncation schedule: steps 1..5 run, the 5th truncates, the 6th is
    // the auto-reset row, repeat.
    let wrap = WrapConfig { time_limit: Some(5), ..WrapConfig::none() };
    let a = run("Pendulum-v1", wrap.clone(), ExecMode::Scalar, 18, 7);
    let b = run("Pendulum-v1", wrap, ExecMode::Vectorized, 18, 7);
    assert_streams_equal(&a, &b, "time-limit");
    assert!(a.done.iter().all(|&d| d == 0), "pendulum cannot terminate");
    for t in 0..18 {
        for e in 0..4 {
            let expect = t % 6 == 4;
            assert_eq!(a.trunc[t * 4 + e] != 0, expect, "trunc schedule at step {t} env {e}");
        }
    }
}

#[test]
fn termination_beats_truncation_across_modes() {
    // CartPole with a generous limit: alternating pushes terminate
    // (done), never truncate; the flags must agree mode-to-mode and
    // never co-fire.
    let wrap = WrapConfig { time_limit: Some(400), ..WrapConfig::none() };
    let a = run("CartPole-v1", wrap.clone(), ExecMode::Scalar, 300, 3);
    let b = run("CartPole-v1", wrap, ExecMode::Vectorized, 300, 3);
    assert_streams_equal(&a, &b, "termination");
    assert!(a.done.iter().any(|&d| d != 0), "cartpole must fall within 300 steps");
    for (k, (&d, &tr)) in a.done.iter().zip(&a.trunc).enumerate() {
        assert!(!(d != 0 && tr != 0), "done and truncated co-fired at row {k}");
    }
}

#[test]
fn reward_clip_bounds_agree_across_modes() {
    let wrap = WrapConfig { reward_clip: true, ..WrapConfig::none() };
    let a = run("Pendulum-v1", wrap.clone(), ExecMode::Scalar, 40, 11);
    let b = run("Pendulum-v1", wrap, ExecMode::Vectorized, 40, 11);
    assert_streams_equal(&a, &b, "reward-clip");
    assert!(a.rew.iter().all(|&r| r == -1.0 || r == 0.0 || r == 1.0), "clip bounds");
    assert!(a.rew.iter().any(|&r| r == -1.0), "pendulum costs must clip to -1");
}

#[test]
fn normalize_obs_running_stats_deterministic_across_modes() {
    let wrap = WrapConfig { normalize_obs: true, ..WrapConfig::none() };
    let a = run("Pendulum-v1", wrap.clone(), ExecMode::Scalar, 60, 5);
    let b = run("Pendulum-v1", wrap.clone(), ExecMode::Vectorized, 60, 5);
    assert_streams_equal(&a, &b, "normalize-obs");
    // Determinism: a repeat run reproduces the stream exactly.
    let a2 = run("Pendulum-v1", wrap.clone(), ExecMode::Scalar, 60, 5);
    let b2 = run("Pendulum-v1", wrap, ExecMode::Vectorized, 60, 5);
    assert_eq!(a.obs, a2.obs, "scalar normalize-obs run not deterministic");
    assert_eq!(b.obs, b2.obs, "vectorized normalize-obs run not deterministic");
    // Sanity: normalization actually transforms the stream.
    let raw = run("Pendulum-v1", WrapConfig::none(), ExecMode::Scalar, 60, 5);
    assert_ne!(a.obs, raw.obs, "normalization must change observations");
    assert!(a.obs.iter().all(|&x| x.abs() <= 10.0), "normalized obs clip bound");
}

#[test]
fn full_wrapper_stack_agrees_across_modes_on_every_family() {
    // The whole stack at once, on one task per env family (classic,
    // walker, dm_control) — Atari is covered (unwrapped) by
    // vector_parity; wrapped Atari is exercised in the pool unit tests.
    let wrap = WrapConfig {
        time_limit: Some(9),
        reward_clip: true,
        normalize_obs: true,
        ..WrapConfig::none()
    };
    for task in ["CartPole-v1", "Hopper-v4", "cheetah_run"] {
        let a = run(task, wrap.clone(), ExecMode::Scalar, 25, 19);
        let b = run(task, wrap.clone(), ExecMode::Vectorized, 25, 19);
        assert_streams_equal(&a, &b, task);
        if task == "cheetah_run" {
            // cheetah_run never terminates, so the 9-step limit *must*
            // show up as truncation (the walkers may die earlier).
            assert!(a.trunc.iter().any(|&t| t != 0), "{task}: 9-step limit must truncate");
        }
    }
}
