//! Integration + property tests over the full pool: async long-tail
//! behaviour, routing invariants under random batch sizes, stress across
//! tasks, and NUMA sharding.

use envpool::pool::{EnvPool, ExecMode, NumaPool, PoolConfig};
use envpool::prop::forall;
use envpool::prop_assert;
use envpool::rng::Pcg32;

#[test]
fn prop_async_pool_serves_every_env_and_routes_correctly() {
    forall("pool-routing", |g| {
        let n = g.usize_in(2, 10);
        let m = g.usize_in(1, n);
        let threads = g.usize_in(1, 3);
        let mut pool = EnvPool::make(
            PoolConfig::new("CartPole-v1")
                .num_envs(n)
                .batch_size(m)
                .num_threads(threads)
                .seed(99),
        )
        .map_err(|e| e.to_string())?;
        pool.async_reset();
        let mut out = pool.make_output();
        let mut outstanding = vec![0u32; n]; // actions in flight per env
        let mut received = vec![0u32; n];
        // after async_reset every env has one implicit in-flight result
        for o in &mut outstanding {
            *o = 1;
        }
        for _ in 0..30 {
            pool.recv_into(&mut out).map_err(|e| e.to_string())?;
            prop_assert!(out.len() == m, "batch size {} != {m}", out.len());
            for &id in &out.env_ids {
                prop_assert!((id as usize) < n, "env id {id} out of range");
                prop_assert!(outstanding[id as usize] > 0, "result for idle env {id}");
                outstanding[id as usize] -= 1;
                received[id as usize] += 1;
            }
            let actions = vec![0.0f32; m];
            pool.send(&actions, &out.env_ids.clone()).map_err(|e| e.to_string())?;
            for &id in &out.env_ids {
                outstanding[id as usize] += 1;
            }
        }
        Ok(())
    });
}

#[test]
fn async_mode_hides_stragglers() {
    // With batch_size < num_envs, recv latency tracks the *fastest* M
    // envs. We can't measure wall-clock reliably on 1 core, but we can
    // verify the scheduling property: a recv never blocks on envs that
    // have no outstanding action.
    let n = 6;
    let m = 2;
    let mut pool = EnvPool::make(
        PoolConfig::new("Pendulum-v1").num_envs(n).batch_size(m).num_threads(2).seed(5),
    )
    .unwrap();
    pool.async_reset();
    let mut out = pool.make_output();
    // drain initial resets
    for _ in 0..n / m {
        pool.recv_into(&mut out).unwrap();
        let actions = vec![0.0f32; m];
        pool.send(&actions, &out.env_ids.clone()).unwrap();
    }
    // now keep only re-sending to whatever returns: the pool must keep
    // producing full batches indefinitely
    for _ in 0..50 {
        pool.recv_into(&mut out).unwrap();
        assert_eq!(out.len(), m);
        let actions = vec![0.1f32; m];
        pool.send(&actions, &out.env_ids.clone()).unwrap();
    }
}

#[test]
fn pool_runs_every_registered_task() {
    for &task in envpool::envs::registry::ALL_TASKS {
        let mut pool = EnvPool::make(
            PoolConfig::new(task).num_envs(2).batch_size(2).num_threads(2).seed(1),
        )
        .unwrap();
        let adim = pool.spec().action_space.dim();
        let mut out = pool.make_output();
        pool.reset_into(&mut out).unwrap();
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..5 {
            let mut actions = Vec::new();
            envpool::coordinator::throughput::random_actions(
                &pool.spec().action_space.clone(),
                out.len(),
                &mut rng,
                &mut actions,
            );
            assert_eq!(actions.len(), out.len() * adim);
            pool.step_into(&actions, &out.env_ids.clone(), &mut out).unwrap();
            assert!(out.obs.iter().all(|x| x.is_finite()), "{task}");
        }
    }
}

#[test]
fn numa_pool_end_to_end() {
    let cfg = PoolConfig::new("Pong-v5").num_envs(4).batch_size(2).num_threads(2).seed(3);
    let mut pool = NumaPool::make(cfg, 2).unwrap();
    pool.async_reset();
    let mut outs = pool.make_outputs();
    for _ in 0..10 {
        pool.recv_all(&mut outs).unwrap();
        let mut ids = vec![];
        let mut actions = vec![];
        for o in &outs {
            for &id in &o.env_ids {
                ids.push(id);
                actions.push((id % 6) as f32);
            }
        }
        pool.send(&actions, &ids).unwrap();
    }
    assert!(pool.total_steps() > 0);
}

#[test]
fn numa_pool_runs_vectorized_walker_shards() {
    // ExecMode plumbed through NumaPool::make: two shards, each a
    // ChunkedThreadPool stepping WalkerVec chunks. 8 envs / 2 nodes ->
    // shards of 4 envs, 2 threads, batch 2 (2 chunks of 2; batch <=
    // num_chunks satisfies the chunked liveness constraint).
    let cfg = PoolConfig::new("Hopper-v4")
        .num_envs(8)
        .batch_size(4)
        .num_threads(4)
        .seed(7)
        .exec_mode(ExecMode::Vectorized);
    let mut pool = NumaPool::make(cfg, 2).unwrap();
    let adim = pool.spec().action_space.dim();
    pool.async_reset();
    let mut outs = pool.make_outputs();
    let mut seen = vec![0u32; 8];
    for _ in 0..20 {
        pool.recv_all(&mut outs).unwrap();
        let mut ids = vec![];
        let mut actions = vec![];
        for o in &outs {
            for (k, &id) in o.env_ids.iter().enumerate() {
                seen[id as usize] += 1;
                ids.push(id);
                for j in 0..adim {
                    actions.push(((id as usize + k + j) % 3) as f32 - 1.0);
                }
            }
            assert!(o.obs.iter().all(|x| x.is_finite()));
        }
        pool.send(&actions, &ids).unwrap();
    }
    assert!(seen.iter().all(|&c| c > 0), "{seen:?}");
    assert!(pool.total_steps() > 0);
}

#[test]
fn numa_async_vec_executor_configuration_runs() {
    // The `envpool-numa-async-vec` executor kind end to end through the
    // throughput driver (the Table 1 row's code path).
    let fps = envpool::coordinator::throughput::run_throughput(
        "Hopper-v4",
        "envpool-numa-async-vec",
        8,
        4,
        4,
        400,
        3,
    )
    .unwrap();
    assert!(fps > 0.0, "numa-async-vec must make progress, got {fps}");
}

#[test]
fn pool_shutdown_is_clean_with_work_in_flight() {
    let mut pool = EnvPool::make(
        PoolConfig::new("Ant-v4").num_envs(8).batch_size(4).num_threads(3).seed(9),
    )
    .unwrap();
    pool.async_reset();
    let mut out = pool.make_output();
    pool.recv_into(&mut out).unwrap();
    let actions = vec![0.0f32; out.len() * pool.spec().action_space.dim()];
    pool.send(&actions, &out.env_ids.clone()).unwrap();
    // drop with in-flight work: must not hang or crash
    pool.close();
}

#[test]
fn atari_pool_no_torn_frames_under_concurrency() {
    // Large (4*84*84) observation rows written concurrently into the
    // state queue must arrive untorn: each row's planes must be finite
    // and in [0,1] and per-env deterministic vs a fresh single env.
    let mut pool = EnvPool::make(
        PoolConfig::new("Pong-v5").num_envs(4).batch_size(2).num_threads(3).seed(21),
    )
    .unwrap();
    pool.async_reset();
    let mut out = pool.make_output();
    for _ in 0..30 {
        pool.recv_into(&mut out).unwrap();
        assert_eq!(out.obs.len(), 2 * 4 * 84 * 84);
        for i in 0..out.len() {
            let row = out.obs_row(i);
            assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)), "corrupt frame");
        }
        let actions = vec![0.0f32; out.len()];
        pool.send(&actions, &out.env_ids.clone()).unwrap();
    }
}

#[test]
fn atari_vectorized_pool_no_torn_frames_on_large_rows() {
    // The two-phase slot_obs_mut/commit path with Atari-sized rows
    // (4*84*84 floats per slot): chunked workers write whole frames into
    // block memory before committing; the consumer must never observe a
    // torn or out-of-range row.
    let mut pool = EnvPool::make(
        PoolConfig::new("Pong-v5")
            .num_envs(4)
            .batch_size(2)
            .num_threads(2)
            .seed(21)
            .exec_mode(ExecMode::Vectorized),
    )
    .unwrap();
    pool.async_reset();
    let mut out = pool.make_output();
    for _ in 0..30 {
        pool.recv_into(&mut out).unwrap();
        assert_eq!(out.obs.len(), 2 * 4 * 84 * 84);
        for i in 0..out.len() {
            let row = out.obs_row(i);
            assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)), "corrupt frame");
        }
        let actions = vec![0.0f32; out.len()];
        pool.send(&actions, &out.env_ids.clone()).unwrap();
    }
}

#[test]
fn prop_sync_pool_equals_forloop_on_random_action_streams() {
    use envpool::executors::{ForLoopExecutor, PoolVectorEnv, VectorEnv};
    forall("sync-parity-random", |g| {
        let n = g.usize_in(1, 5);
        let seed = g.usize_in(0, 1000) as u64;
        let steps = g.usize_in(5, 40);
        let mut a = ForLoopExecutor::new("MountainCar-v0", n, seed).map_err(|e| e.to_string())?;
        let pool = EnvPool::make(
            PoolConfig::new("MountainCar-v0").num_envs(n).batch_size(n).num_threads(2).seed(seed),
        )
        .map_err(|e| e.to_string())?;
        let mut b = PoolVectorEnv::new(pool).map_err(|e| e.to_string())?;
        let mut oa = a.make_output();
        let mut ob = b.make_output();
        a.reset(&mut oa).map_err(|e| e.to_string())?;
        b.reset(&mut ob).map_err(|e| e.to_string())?;
        prop_assert!(oa.obs == ob.obs, "reset mismatch");
        for s in 0..steps {
            let actions: Vec<f32> = (0..n).map(|k| ((s * 7 + k * 3) % 3) as f32).collect();
            a.step(&actions, &mut oa).map_err(|e| e.to_string())?;
            b.step(&actions, &mut ob).map_err(|e| e.to_string())?;
            prop_assert!(oa.rew == ob.rew, "reward mismatch at {s}");
            prop_assert!(oa.obs == ob.obs, "obs mismatch at {s}");
        }
        Ok(())
    });
}

#[test]
fn double_close_and_use_after_close_are_safe() {
    let mut pool = EnvPool::make(
        PoolConfig::new("CartPole-v1").num_envs(2).batch_size(2).num_threads(1).seed(0),
    )
    .unwrap();
    let mut out = pool.make_output();
    pool.reset_into(&mut out).unwrap();
    pool.close();
    pool.close(); // idempotent
    // sends after close enqueue but nobody serves them; recv must report
    // the closed pool rather than hang or crash
    let _ = pool.send(&[0.0, 0.0], &[0, 1]);
    assert!(matches!(
        pool.recv_into_timeout(&mut out, std::time::Duration::from_millis(50)),
        Err(envpool::Error::Closed)
    ));
    assert!(matches!(pool.recv_into(&mut out), Err(envpool::Error::Closed)));
}
