//! Determinism across execution configurations: the same seed must give
//! **identical per-env episode returns** no matter how many worker
//! threads serve the pool, what batch size `recv` uses, or which
//! `ExecMode` steps the envs. Per-env RNG streams keyed by global env id
//! plus a per-env action policy make trajectories a function of
//! `(seed, env_id)` alone.

use envpool::envs::spec::ActionSpace;
use envpool::pool::{EnvPool, ExecMode, PoolConfig};

/// Drive an async pool until every env has completed `episodes`
/// episodes; return the first `episodes` episodic returns per env.
///
/// The action for an env is a pure function of `(env_id, per-env action
/// index)`, so each env sees the same action sequence in every
/// configuration regardless of scheduling or batching.
fn first_episode_returns(
    task: &str,
    n: usize,
    batch: usize,
    threads: usize,
    mode: ExecMode,
    episodes: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut pool = EnvPool::make(
        PoolConfig::new(task)
            .num_envs(n)
            .batch_size(batch)
            .num_threads(threads)
            .seed(seed)
            .exec_mode(mode),
    )
    .unwrap();
    let discrete = match pool.spec().action_space {
        ActionSpace::Discrete(k) => k as u64,
        ActionSpace::Continuous { .. } => 0,
    };
    // Episodes are bounded by the task's truncation limit, so this recv
    // budget is generous; the panic below fires if it is insufficient.
    let ep_bound = pool.spec().max_episode_steps + 60;
    let max_recvs = (episodes + 1) * ep_bound * n / batch + 50;
    pool.async_reset();
    let mut out = pool.make_output();
    let mut sent = vec![0u64; n];
    let mut ep_ret = vec![0.0f32; n];
    let mut returns: Vec<Vec<f32>> = vec![Vec::new(); n];
    let mut actions: Vec<f32> = Vec::new();
    for _ in 0..max_recvs {
        if returns.iter().all(|r| r.len() >= episodes) {
            break;
        }
        pool.recv_into(&mut out).unwrap();
        let ids = out.env_ids.clone();
        actions.clear();
        for (row, &id) in ids.iter().enumerate() {
            let i = id as usize;
            ep_ret[i] += out.rew[row];
            if out.finished(row) {
                returns[i].push(ep_ret[i]);
                ep_ret[i] = 0.0;
            }
            let t = sent[i];
            sent[i] += 1;
            if discrete > 0 {
                actions.push(((id as u64 * 3 + t * 5) % discrete) as f32);
            } else {
                actions.push(((id as u64 + t) % 7) as f32 / 3.5 - 1.0);
            }
        }
        pool.send(&actions, &ids).unwrap();
    }
    for (i, r) in returns.iter_mut().enumerate() {
        assert!(r.len() >= episodes, "env {i} finished only {} episodes", r.len());
        r.truncate(episodes);
    }
    returns
}

/// The (threads, batch_size, mode) grid every task is checked over.
/// Vectorized async rows keep `batch_size <= num_chunks` (the pool's
/// liveness constraint); with 2 threads there are 2 chunks for every
/// `n >= 2` here.
fn grid(n: usize) -> Vec<(usize, usize, ExecMode)> {
    vec![
        (1, n, ExecMode::Scalar),
        (2, n, ExecMode::Scalar),
        (3, n.div_ceil(2), ExecMode::Scalar),
        (1, n, ExecMode::Vectorized),
        (2, n, ExecMode::Vectorized),
        (2, 2, ExecMode::Vectorized),
        (3, 1, ExecMode::Vectorized),
        (2, 1, ExecMode::Scalar),
    ]
}

fn check_task(task: &str, n: usize, episodes: usize, seed: u64) {
    let reference = first_episode_returns(task, n, n, 1, ExecMode::Scalar, episodes, seed);
    for (threads, batch, mode) in grid(n) {
        let got = first_episode_returns(task, n, batch, threads, mode, episodes, seed);
        assert_eq!(
            reference, got,
            "{task}: returns diverge at threads={threads} batch={batch} mode={mode:?}"
        );
    }
}

#[test]
fn mountain_car_returns_invariant_to_execution_config() {
    // Episodes are bounded by the 200-step truncation, so every config
    // completes them quickly.
    check_task("MountainCar-v0", 6, 2, 1234);
}

#[test]
fn pendulum_returns_invariant_to_execution_config() {
    // Continuous actions; episodes truncate at exactly 200 steps.
    check_task("Pendulum-v1", 5, 2, 99);
}

#[test]
fn cartpole_returns_invariant_to_execution_config() {
    check_task("CartPole-v1", 4, 3, 7);
}
