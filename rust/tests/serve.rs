//! End-to-end tests for `envpool serve`: shared-memory clients stepping a
//! live server, trajectory parity against the in-process pool, and
//! client-death chaos (both an in-process crashed client and a real
//! SIGKILLed `envpool attach` subprocess).

use envpool::config::ServeConfig;
use envpool::executors::serve::PoolServer;
use envpool::executors::{PoolVectorEnv, ShmClient, VectorEnv};
use envpool::pool::{EnvPool, ExecMode, PoolConfig};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

fn sock_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("envpool-it-{name}-{}.sock", std::process::id()))
}

fn serve_cfg(name: &str, clients: usize, lease: usize, seed: u64) -> ServeConfig {
    ServeConfig::new("CartPole-v1", sock_path(name))
        .max_clients(clients)
        .lease_size(lease)
        .num_threads(2)
        .seed(seed)
}

/// Attach with retries: a lease freed by detach/death becomes claimable
/// immediately but admission can race the reclaim by a few milliseconds.
fn attach_retry(socket: &Path, k: usize) -> ShmClient {
    let t0 = Instant::now();
    loop {
        match ShmClient::attach(socket, k) {
            Ok(c) => return c,
            Err(e) => {
                assert!(t0.elapsed() < Duration::from_secs(10), "attach never admitted: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// The shared deterministic policy: action for global env id `g` at step
/// `t`. Both the served clients and the in-process reference use it, so
/// trajectories must match env-for-env. Five-step runs in one direction
/// (phase-shifted by env id) destabilize CartPole quickly, so every first
/// episode terminates well inside the test budget.
fn policy(t: usize, g: usize) -> f32 {
    ((t / 5 + g) % 2) as f32
}

/// (episode length, episode return) of each env's first episode.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
struct Episode {
    len: u32,
    ret: f32,
}

/// Two clients with disjoint leases must see exactly the per-env episodes
/// an in-process pool produces with the same seed and policy: env streams
/// are keyed `(seed, env_id)` and every attach resets its lease once.
#[test]
fn two_attached_clients_match_the_in_process_pool() {
    const K: usize = 4;
    const N: usize = 2 * K;
    const SEED: u64 = 9;
    const STEPS: usize = 400;

    // Reference: all 8 envs in one synchronous in-process pool.
    let pool = EnvPool::make(
        PoolConfig::new("CartPole-v1")
            .num_envs(N)
            .batch_size(N)
            .num_threads(2)
            .seed(SEED)
            .exec_mode(ExecMode::Scalar),
    )
    .unwrap();
    let mut reference = PoolVectorEnv::new(pool).unwrap();
    let mut out = reference.make_output();
    reference.reset(&mut out).unwrap();
    let reference_reset_obs = out.obs.clone();
    let mut want = [Episode::default(); N];
    let mut open = [true; N];
    for t in 0..STEPS {
        let acts: Vec<f32> = (0..N).map(|g| policy(t, g)).collect();
        reference.step(&acts, &mut out).unwrap();
        for g in 0..N {
            if open[g] {
                want[g].len += 1;
                want[g].ret += out.rew[g];
                open[g] &= out.done[g] == 0 && out.trunc[g] == 0;
            }
        }
    }
    assert!(open.iter().all(|o| !o), "400 steps must finish every first episode");

    // Served: the same 8 envs behind two attached clients.
    let server = PoolServer::start(serve_cfg("determinism", 2, K, SEED)).unwrap();
    let mut a = ShmClient::attach(server.socket_path(), K).unwrap();
    let mut b = ShmClient::attach(server.socket_path(), K).unwrap();
    let mut got = [Episode::default(); N];
    let mut open = [true; N];
    for client in [&mut a, &mut b] {
        let first = client.first_env() as usize;
        let mut out = client.make_output();
        client.reset(&mut out).unwrap();
        let dim = client.spec().obs_dim();
        assert_eq!(
            out.obs,
            reference_reset_obs[first * dim..(first + K) * dim],
            "reset obs of envs {first}..{} disagree with the in-process pool",
            first + K
        );
        for t in 0..STEPS {
            let acts: Vec<f32> = (0..K).map(|i| policy(t, first + i)).collect();
            client.step(&acts, &mut out).unwrap();
            for i in 0..K {
                let g = first + i;
                if open[g] {
                    got[g].len += 1;
                    got[g].ret += out.rew[i];
                    open[g] &= out.done[i] == 0 && out.trunc[i] == 0;
                }
            }
        }
    }
    assert_eq!(got, want, "served first episodes diverge from the in-process pool");
    a.detach().unwrap();
    b.detach().unwrap();
    server.stop();
}

/// An in-process client that dies without detaching (slammed socket, no
/// goodbye) must have its lease drained, reset, and handed to the next
/// client with a sane initial batch.
#[test]
fn crashed_client_lease_is_reclaimed_for_the_next_attach() {
    const K: usize = 2;
    let server = PoolServer::start(serve_cfg("crash", 1, K, 11)).unwrap();

    let mut c1 = ShmClient::attach(server.socket_path(), K).unwrap();
    let mut out = c1.make_output();
    c1.reset(&mut out).unwrap();
    // Die with a wave still in flight so the reclaim has to drain it.
    c1.send_wave(&[1.0, 0.0]).unwrap();
    c1.simulate_crash();

    let t0 = Instant::now();
    while server.reclaims() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "lease never reclaimed");
        std::thread::sleep(Duration::from_millis(10));
    }

    let mut c2 = attach_retry(server.socket_path(), K);
    let mut out = c2.make_output();
    c2.reset(&mut out).unwrap();
    assert_eq!(out.len(), K);
    assert!(out.obs.iter().all(|x| x.is_finite()), "post-reclaim obs not sane: {:?}", out.obs);
    for t in 0..10 {
        let acts: Vec<f32> = (0..K).map(|i| policy(t, i)).collect();
        c2.step(&acts, &mut out).unwrap();
    }
    assert_eq!(server.attaches(), 2);
    c2.detach().unwrap();
    server.stop();
}

/// The full kill-a-client story: a *real* `envpool attach` process is
/// SIGKILLed mid-run; the server must reclaim the lease and admit a fresh
/// client that sees freshly-reset envs.
#[test]
fn sigkilled_attach_subprocess_is_reclaimed() {
    const K: usize = 4;
    let server = PoolServer::start(serve_cfg("sigkill", 1, K, 13)).unwrap();

    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_envpool"))
        .args([
            "attach",
            "--socket",
            &server.socket_path().display().to_string(),
            "--num-envs",
            &K.to_string(),
            // Far more steps than it will live to take.
            "--steps",
            "100000000",
        ])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn envpool attach");

    // Wait until it actually holds the lease, then kill it mid-batch.
    let t0 = Instant::now();
    while server.attaches() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(30), "client never attached");
        std::thread::sleep(Duration::from_millis(10));
    }
    std::thread::sleep(Duration::from_millis(100)); // let it step a while
    child.kill().expect("SIGKILL the attached client");
    let _ = child.wait();

    let t0 = Instant::now();
    while server.reclaims() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "lease never reclaimed after SIGKILL");
        std::thread::sleep(Duration::from_millis(10));
    }

    let mut c = attach_retry(server.socket_path(), K);
    let mut out = c.make_output();
    c.reset(&mut out).unwrap();
    assert_eq!(out.len(), K);
    assert_eq!(out.env_ids, [0, 1, 2, 3]);
    assert!(out.obs.iter().all(|x| x.is_finite()));
    for t in 0..20 {
        let acts: Vec<f32> = (0..K).map(|i| policy(t, i)).collect();
        c.step(&acts, &mut out).unwrap();
    }
    c.detach().unwrap();
    server.stop();
}
