//! Heterogeneous scenario pools: mixed-task parity and replayability.
//!
//! The contract under test (see `config/scenario.rs` and
//! `pool/hetero.rs`): a group inside a mixed pool is seeded with the
//! **group seed** and group-local env ids, so its per-env episodes are
//! bitwise identical to a homogeneous pool built from the same task,
//! seed and wrapper stack — routing through the union spec, the
//! env_id -> (group, lane) map, the ragged obs arenas and the action
//! re-striding must be invisible in the data.
//!
//! Bitwise scope mirrors the repo's SIMD parity contracts: classic
//! control is bitwise at every lane width, the walker family and Atari
//! at width 1 — so the all-width sweep uses a classic trio and the
//! classic+walker+Atari mix pins width 1 across both exec modes.

use envpool::config::ScenarioConfig;
use envpool::envs::registry;
use envpool::envs::spec::ActionSpace;
use envpool::executors::{PoolVectorEnv, VectorEnv};
use envpool::pool::{EnvPool, ExecMode, PoolConfig};
use envpool::simd::LanePass;

/// Everything a pool emitted over a run, in env-id-major stream order.
#[derive(Debug, Clone, PartialEq)]
struct Streams {
    obs: Vec<f32>,
    rew: Vec<f32>,
    done: Vec<u8>,
    trunc: Vec<u8>,
}

/// Deterministic action for `(lane, step)` under a **group's** action
/// space — both sides of every comparison key actions off the group
/// lane, so the mixed pool and the homogeneous oracle agree exactly.
fn fill_action(space: &ActionSpace, lane: usize, step: usize, out: &mut [f32]) {
    match *space {
        ActionSpace::Discrete(k) => out[0] = ((step * 5 + lane * 3) % k) as f32,
        ActionSpace::Continuous { dim, low, high } => {
            for (d, slot) in out.iter_mut().enumerate().take(dim) {
                let t = ((step * 7 + lane * 5 + d * 11) % 13) as f32 / 12.0;
                *slot = low + t * (high - low);
            }
        }
    }
}

/// Drive a sync pool for `steps` rounds. `lane_of(env)` gives the
/// group-local lane and per-group action space used to key actions.
fn drive(pool: EnvPool, steps: usize, lane_of: &dyn Fn(usize) -> (usize, ActionSpace)) -> Streams {
    let spec = pool.spec().clone();
    let union_adim = spec.action_space.dim();
    let n = pool.config().num_envs;
    let mut v = PoolVectorEnv::new(pool).unwrap();
    let mut out = v.make_output();
    let mut st = Streams { obs: Vec::new(), rew: Vec::new(), done: Vec::new(), trunc: Vec::new() };
    v.reset(&mut out).unwrap();
    st.obs.extend_from_slice(&out.obs);
    let mut actions = vec![0.0f32; n * union_adim];
    for step in 0..steps {
        actions.fill(0.0);
        for e in 0..n {
            let (lane, space) = lane_of(e);
            let adim = space.dim();
            fill_action(&space, lane, step, &mut actions[e * union_adim..e * union_adim + adim]);
        }
        v.step(&actions, &mut out).unwrap();
        st.obs.extend_from_slice(&out.obs);
        st.rew.extend_from_slice(&out.rew);
        st.done.extend_from_slice(&out.done);
        st.trunc.extend_from_slice(&out.trunc);
    }
    st
}

/// Per-env slices of a mixed stream must equal the homogeneous group
/// stream bitwise, and the union-row padding must be exactly zero.
fn assert_group_parity(
    mixed: &Streams,
    homo: &Streams,
    n_mixed: usize,
    union_dim: usize,
    first_env: usize,
    count: usize,
    group_dim: usize,
    steps: usize,
    ctx: &str,
) {
    for s in 0..=steps {
        for l in 0..count {
            let e = first_env + l;
            let m = &mixed.obs[(s * n_mixed + e) * union_dim..(s * n_mixed + e + 1) * union_dim];
            let h = &homo.obs[(s * count + l) * group_dim..(s * count + l + 1) * group_dim];
            assert_eq!(&m[..group_dim], h, "{ctx}: obs diverge, step {s} env {e}");
            assert!(
                m[group_dim..].iter().all(|&x| x == 0.0),
                "{ctx}: padding not zero, step {s} env {e}"
            );
        }
    }
    for s in 0..steps {
        for l in 0..count {
            let e = first_env + l;
            assert_eq!(
                mixed.rew[s * n_mixed + e],
                homo.rew[s * count + l],
                "{ctx}: rewards diverge, step {s} env {e}"
            );
            assert_eq!(
                mixed.done[s * n_mixed + e],
                homo.done[s * count + l],
                "{ctx}: dones diverge, step {s} env {e}"
            );
            assert_eq!(
                mixed.trunc[s * n_mixed + e],
                homo.trunc[s * count + l],
                "{ctx}: truncs diverge, step {s} env {e}"
            );
        }
    }
}

fn mixed_pool(sc: &ScenarioConfig, seed: u64, mode: ExecMode, lp: LanePass) -> EnvPool {
    EnvPool::make(
        PoolConfig::new("scenario")
            .scenario(sc.clone())
            .sync()
            .num_threads(sc.groups.len())
            .seed(seed)
            .exec_mode(mode)
            .lane_pass(lp),
    )
    .unwrap()
}

fn homo_pool(sc: &ScenarioConfig, gi: usize, pool_seed: u64, mode: ExecMode, lp: LanePass) -> EnvPool {
    let g = &sc.groups[gi];
    EnvPool::make(
        PoolConfig::new(&g.task_id)
            .num_envs(g.count)
            .batch_size(g.count)
            .num_threads(1)
            .seed(sc.group_seed(gi, pool_seed))
            .exec_mode(mode)
            .lane_pass(lp)
            .wrappers(g.wrap.clone()),
    )
    .unwrap()
}

/// Run the full mixed-vs-homogeneous comparison for one scenario at one
/// (exec mode, lane pass) point. `steps` is chosen so terminations and
/// wrapper truncations auto-reset lanes mid-run on both sides.
fn check_scenario_parity(sc: &ScenarioConfig, pool_seed: u64, mode: ExecMode, lp: LanePass, steps: usize) {
    let spec = registry::scenario_spec(sc).unwrap();
    let union_dim = spec.obs_dim();
    let n = sc.num_envs();
    let views = spec.groups.clone();
    let lane_of = move |e: usize| {
        let g = views.iter().find(|v| e >= v.first_env && e < v.first_env + v.count).unwrap();
        (e - g.first_env, g.spec.action_space.clone())
    };
    let mixed = drive(mixed_pool(sc, pool_seed, mode, lp), steps, &lane_of);
    for (gi, view) in spec.groups.iter().enumerate() {
        let space = view.spec.action_space.clone();
        let homo = drive(homo_pool(sc, gi, pool_seed, mode, lp), steps, &move |l| {
            (l, space.clone())
        });
        assert_group_parity(
            &mixed,
            &homo,
            n,
            union_dim,
            view.first_env,
            view.count,
            view.spec.obs_dim(),
            steps,
            &format!("{}/{mode:?}/width{}", view.task_id, lp.width()),
        );
    }
}

const CLASSIC_TRIO: &str = "\
[group]
task = CartPole-v1
count = 4
seed = 101
time_limit = 50
reward_clip = true

[group]
task = Pendulum-v1
count = 4
seed = 202

[group]
task = MountainCar-v0
count = 8
seed = 303
";

/// Classic control is bitwise at every lane width, so the 3-group
/// classic mix must match its homogeneous oracles at widths 1, 4, 8 —
/// with the CartPole group terminating and hitting its 50-step wrapper
/// truncation (auto-resets) well inside the 70-step run.
#[test]
fn mixed_classic_pool_matches_homogeneous_pools_at_all_lane_widths() {
    let sc = ScenarioConfig::parse(CLASSIC_TRIO).unwrap();
    for lp in [LanePass::Scalar, LanePass::Width4, LanePass::Width8] {
        check_scenario_parity(&sc, 7, ExecMode::Vectorized, lp, 70);
    }
}

/// The paper-shaped mix — classic + walker + Atari — at lane width 1
/// (the walker family's bitwise contract), across both exec modes:
/// scalar per-env lanes and full-width vectorized group kernels must
/// both reproduce the homogeneous pools exactly.
#[test]
fn mixed_classic_walker_atari_pool_matches_homogeneous_pools_in_both_exec_modes() {
    let sc = ScenarioConfig::parse(
        "\
[group]
task = CartPole-v1
count = 2
seed = 11
time_limit = 40
reward_clip = true

[group]
task = Hopper-v4
count = 2
seed = 22

[group]
task = Pong-v5
count = 2
seed = 33
",
    )
    .unwrap();
    for mode in [ExecMode::Scalar, ExecMode::Vectorized] {
        check_scenario_parity(&sc, 9, mode, LanePass::Scalar, 50);
    }
}

/// Replayability: the same scenario text + pool seed reproduces the
/// same jittered physics and therefore bitwise-identical episode
/// streams and returns; a different pool seed redraws the jitters (no
/// explicit group seeds here) and the trajectories move.
#[test]
fn scenario_jitter_is_replayable_from_file_and_seed() {
    const JITTERED: &str = "\
[group]
task = CartPole-v1
count = 4
time_limit = 60
jitter.length = 0.4 0.6

[group]
task = Pendulum-v1
count = 4
jitter.gravity = 8.0 12.0
";
    let steps = 60;
    let run = |pool_seed: u64| {
        let sc = ScenarioConfig::parse(JITTERED).unwrap();
        let spec = registry::scenario_spec(&sc).unwrap();
        let views = spec.groups.clone();
        let lane_of = move |e: usize| {
            let g = views.iter().find(|v| e >= v.first_env && e < v.first_env + v.count).unwrap();
            (e - g.first_env, g.spec.action_space.clone())
        };
        drive(mixed_pool(&sc, pool_seed, ExecMode::Vectorized, LanePass::Auto), steps, &lane_of)
    };
    let a = run(5);
    let b = run(5);
    assert_eq!(a, b, "same scenario + seed must replay bitwise");
    let n = 8;
    let returns = |st: &Streams, e: usize| -> f32 {
        (0..steps).map(|s| st.rew[s * n + e]).sum()
    };
    for e in 0..n {
        assert_eq!(returns(&a, e).to_bits(), returns(&b, e).to_bits(), "env {e} return drifted");
    }
    let c = run(6);
    assert_ne!(a, c, "a different pool seed must redraw the jittered physics");
}

/// The checked-in example scenario must keep loading, round-trip
/// through the canonical text form, and build a real grouped pool.
#[test]
fn checked_in_example_scenario_loads_and_round_trips() {
    let path = format!("{}/../examples/scenarios/mixed.scn", env!("CARGO_MANIFEST_DIR"));
    let sc = ScenarioConfig::load(&path).unwrap();
    assert_eq!(
        ScenarioConfig::parse(&sc.to_text()).unwrap(),
        sc,
        "mixed.scn must round-trip through to_text"
    );
    let tasks: Vec<&str> = sc.groups.iter().map(|g| g.task_id.as_str()).collect();
    assert_eq!(tasks, ["CartPole-v1", "Hopper-v4", "Pong-v5"]);
    let spec = registry::scenario_spec(&sc).unwrap();
    assert!(spec.is_grouped());
    assert_eq!(spec.obs_dim(), 4 * 84 * 84, "union obs must be the stacked Atari frame");
    assert!(spec.uniform_group_spec().is_none(), "a 3-task mix has no uniform spec");
    let pool = registry::make_scenario_pool(&sc, 0).unwrap();
    use envpool::envs::vector::VecEnv as _;
    assert_eq!(pool.num_envs(), sc.num_envs());
}
