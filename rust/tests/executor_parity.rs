//! Integration: all executors produce identical trajectories for the
//! same seeds and actions — the semantic guarantee behind the paper's
//! "pure speedup without cost" claim — including the subprocess executor
//! (which spawns real worker processes of the `envpool` binary).

use envpool::executors::{ForLoopExecutor, PoolVectorEnv, SubprocessExecutor, VectorEnv};
use envpool::pool::{EnvPool, PoolConfig};

fn set_worker_bin() {
    // CARGO_BIN_EXE_* is provided to integration tests at compile time.
    std::env::set_var("ENVPOOL_WORKER_BIN", env!("CARGO_BIN_EXE_envpool"));
}

fn run_trajectory(ex: &mut dyn VectorEnv, steps: usize) -> (Vec<f32>, Vec<u8>, f32) {
    let n = ex.num_envs();
    let adim = ex.spec().action_space.dim();
    let mut out = ex.make_output();
    ex.reset(&mut out).unwrap();
    let mut rewards = Vec::new();
    let mut dones = Vec::new();
    let mut obs_hash = 0.0f32;
    for step in 0..steps {
        let actions: Vec<f32> =
            (0..n * adim).map(|k| ((step + k) % 2) as f32).collect();
        ex.step(&actions, &mut out).unwrap();
        rewards.extend_from_slice(&out.rew);
        dones.extend_from_slice(&out.done);
        obs_hash += out.obs.iter().sum::<f32>();
    }
    (rewards, dones, obs_hash)
}

#[test]
fn all_executors_agree_on_cartpole() {
    set_worker_bin();
    let seed = 123;
    let n = 3;
    let steps = 150;

    let mut forloop = ForLoopExecutor::new("CartPole-v1", n, seed).unwrap();
    let a = run_trajectory(&mut forloop, steps);

    let pool = EnvPool::make(
        PoolConfig::new("CartPole-v1").num_envs(n).batch_size(n).num_threads(2).seed(seed),
    )
    .unwrap();
    let mut poolv = PoolVectorEnv::new(pool).unwrap();
    let b = run_trajectory(&mut poolv, steps);

    let mut subproc = SubprocessExecutor::new("CartPole-v1", n, seed).unwrap();
    let c = run_trajectory(&mut subproc, steps);

    assert_eq!(a.0, b.0, "forloop vs envpool rewards");
    assert_eq!(a.1, b.1, "forloop vs envpool dones");
    assert_eq!(a.2, b.2, "forloop vs envpool obs hash");
    assert_eq!(a.0, c.0, "forloop vs subprocess rewards");
    assert_eq!(a.1, c.1, "forloop vs subprocess dones");
    assert_eq!(a.2, c.2, "forloop vs subprocess obs hash");
}

#[test]
fn executors_agree_on_continuous_task() {
    set_worker_bin();
    let seed = 77;
    let n = 2;
    let steps = 60;

    let mut forloop = ForLoopExecutor::new("Pendulum-v1", n, seed).unwrap();
    let a = run_trajectory(&mut forloop, steps);

    let mut subproc = SubprocessExecutor::new("Pendulum-v1", n, seed).unwrap();
    let c = run_trajectory(&mut subproc, steps);

    assert_eq!(a.0, c.0);
    assert_eq!(a.2, c.2);
}

#[test]
fn subprocess_atari_roundtrip() {
    set_worker_bin();
    // Full 4x84x84 frames across process boundaries.
    let mut ex = SubprocessExecutor::new("Pong-v5", 2, 5).unwrap();
    let mut out = ex.make_output();
    ex.reset(&mut out).unwrap();
    assert_eq!(out.obs.len(), 2 * 4 * 84 * 84);
    for step in 0..20 {
        let actions = vec![(step % 6) as f32, ((step + 3) % 6) as f32];
        ex.step(&actions, &mut out).unwrap();
        assert!(out.obs.iter().all(|x| x.is_finite()));
    }
}
