//! Chaos tests for the subprocess executor's failure paths: a worker
//! killed mid-run must surface as `Error::Ipc` on the next step (never a
//! hang), and executor teardown must complete in bounded time even when
//! children are already dead.

use envpool::executors::{SubprocessExecutor, VectorEnv};
use envpool::Error;
use std::time::{Duration, Instant};

fn executor(num_envs: usize) -> SubprocessExecutor {
    // CARGO_BIN_EXE_* is provided to integration tests at compile time.
    std::env::set_var("ENVPOOL_WORKER_BIN", env!("CARGO_BIN_EXE_envpool"));
    SubprocessExecutor::new("CartPole-v1", num_envs, 3).unwrap()
}

#[test]
fn killed_worker_surfaces_as_ipc_error_not_a_hang() {
    let mut ex = executor(3);
    let mut out = ex.make_output();
    ex.reset(&mut out).unwrap();
    let acts = vec![1.0f32; 3];
    ex.step(&acts, &mut out).unwrap();

    ex.kill_worker(1);
    let t0 = Instant::now();
    // Depending on timing the failure lands on the scatter (broken pipe)
    // or the gather (EOF on the dead worker's stdout); both must be Ipc.
    let err = ex.step(&acts, &mut out).unwrap_err();
    assert!(matches!(err, Error::Ipc(_)), "expected Error::Ipc, got {err}");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "step against a dead worker took {:?}",
        t0.elapsed()
    );
    assert!(err.to_string().contains("worker 1"), "got {err}");
}

#[test]
fn drop_with_dead_workers_completes_in_bounded_time() {
    let mut ex = executor(2);
    let mut out = ex.make_output();
    ex.reset(&mut out).unwrap();
    ex.kill_worker(0);
    let t0 = Instant::now();
    drop(ex);
    // Close fan-out + bounded reap: well under the per-worker shutdown
    // deadline, and crucially not an unbounded `wait()` hang.
    assert!(
        t0.elapsed() < Duration::from_secs(6),
        "teardown with a dead worker took {:?}",
        t0.elapsed()
    );
}
