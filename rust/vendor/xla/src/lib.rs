//! Vendored stub of the `xla` PJRT bindings (API-compatible with the
//! subset the parent crate uses).
//!
//! The build environment carries no XLA/PJRT shared libraries, so this
//! stub keeps the parent crate compiling and its pure-Rust tiers fully
//! testable:
//!
//! - [`Literal`] is a **real** host-side f32 tensor (construct, reshape,
//!   read back) — everything host-only works exactly as with the real
//!   bindings.
//! - [`PjRtClient::cpu`] returns [`Error::Unavailable`]; since every
//!   device object ([`PjRtBuffer`], [`PjRtLoadedExecutable`]) can only be
//!   created through a client, device paths are cleanly unreachable and
//!   callers gate on the error (the parent crate's tests skip).
//!
//! Swapping this path dependency for the actual bindings restores the
//! full runtime without any source change in the parent crate.

use std::path::Path;

/// Error type mirroring the real bindings' surface.
#[derive(Debug, Clone)]
pub enum Error {
    /// The PJRT runtime is not present in this build.
    Unavailable(String),
    /// Malformed usage of the host-side tensor API.
    Shape(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(m) => write!(f, "PJRT unavailable: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types the host tensor API can read back. Only `f32` is stored;
/// the trait exists so call sites can keep the real bindings' turbofish.
pub trait NativeType: Copy {
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> Self {
        x
    }
}

/// Host tensor shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side f32 tensor (or a tuple of them), mirroring `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64], tuple: None }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { data: vec![x], dims: vec![], tuple: None }
    }

    /// Tuple literal (what executables return with `return_tuple=True`).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { data: Vec::new(), dims: Vec::new(), tuple: Some(elems) }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), tuple: None })
    }

    /// Read the tensor back as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error::Shape("to_vec on a tuple literal".into()));
        }
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    /// First element (scalar reads).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        match self.data.first() {
            Some(&x) => Ok(T::from_f32(x)),
            None => Err(Error::Shape("empty literal".into())),
        }
    }

    /// Array shape (error for tuple literals).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error::Shape("array_shape on a tuple literal".into()));
        }
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(elems) => Ok(elems.clone()),
            None => Err(Error::Shape("to_tuple on a non-tuple literal".into())),
        }
    }
}

fn unavailable<T>() -> Result<T> {
    Err(Error::Unavailable(
        "this build vendors the stub xla crate (no PJRT shared library); \
         device execution is disabled"
            .into(),
    ))
}

/// Parsed HLO module (held as raw text in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO-text artifact. Parsing/verification happens at compile
    /// time in the real bindings; the stub only checks readability.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path.as_ref()) {
            Ok(text) => Ok(HloModuleProto { text }),
            Err(e) => Err(Error::Shape(format!("{}: {e}", path.as_ref().display()))),
        }
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
}

/// PJRT client handle. Construction always fails in the stub, which makes
/// every device object below unreachable (their methods exist only so the
/// parent crate typechecks).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

/// Device buffer handle (unreachable in the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle (unreachable in the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: AsRef<PjRtBuffer>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

impl AsRef<PjRtBuffer> for PjRtBuffer {
    fn as_ref(&self) -> &PjRtBuffer {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        let t = Literal::tuple(vec![Literal::scalar(1.0), Literal::vec1(&[2.0, 3.0])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT unavailable"));
    }
}
