//! Bench: Table 2h — heterogeneous scenario-pool overhead.
//!
//! A 3-group mixed scenario (CartPole + Pendulum + MountainCar) runs
//! behind one `GroupedVecEnv` pool and is compared against the same
//! three groups executed as separate homogeneous pools, back to back,
//! with the same thread budget. The acceptance gate (full mode only):
//! the mixed pool must reach >= 0.9x the aggregate homogeneous
//! throughput — routing through the env_id -> (group, lane) map, the
//! ragged obs arenas and the per-group action re-striding must cost
//! less than 10%.
//!
//! All three tasks are classic-control (frame multiplier 1), so the
//! weighted frames/s the scenario runner reports equals env-steps/s
//! and is directly comparable with `run_throughput_lanes`.
//!
//! `cargo bench --bench table2h_hetero` (ENVPOOL_BENCH_QUICK=1 for a
//! fast CI pass that skips the gate).

use envpool::bench_util::Bencher;
use envpool::config::ScenarioConfig;
use envpool::coordinator::throughput::{run_throughput_lanes, run_throughput_scenario};
use envpool::metrics::table::{fmt_fps, Table};
use envpool::simd::LanePass;

/// The mixed pool under test: three full-width classic groups, with a
/// jitter entry so the per-lane parameter path is on the measured path.
fn scenario(counts: [usize; 3]) -> ScenarioConfig {
    let text = format!(
        "[group]\n\
         task = CartPole-v1\n\
         count = {}\n\
         jitter.length = 0.4 0.6\n\
         \n\
         [group]\n\
         task = Pendulum-v1\n\
         count = {}\n\
         param.gravity = 9.81\n\
         \n\
         [group]\n\
         task = MountainCar-v0\n\
         count = {}\n",
        counts[0], counts[1], counts[2]
    );
    ScenarioConfig::parse(&text).expect("bench scenario parses")
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    // Group widths stay multiples of 8 so every SIMD lane width packs
    // the groups without remainder lanes.
    let counts: [usize; 3] = if quick { [8, 8, 8] } else { [96, 96, 64] };
    let rounds: u64 = if quick { 64 } else { 2_000 };
    let total: usize = counts.iter().sum();
    // One worker per group chunk; the homogeneous baselines get the
    // same budget so the comparison is thread-for-thread fair.
    let threads = 3usize;
    let seed = 7u64;
    let sc = scenario(counts);
    let tasks = ["CartPole-v1", "Pendulum-v1", "MountainCar-v0"];

    println!("== Table 2h: mixed scenario pool vs homogeneous pools ==");
    println!(
        "(3 groups, {total} envs total, {threads} threads, sync-vec, auto lane width = {})",
        LanePass::Auto.width()
    );

    // Mixed: one pool, one chunk per group, measured as one unit.
    let mixed_steps = rounds * total as u64;
    let mut mixed_fps = 0.0;
    b.run("table2h/mixed/3-group", mixed_steps as f64, || {
        mixed_fps =
            run_throughput_scenario(&sc, "envpool-sync-vec", threads, mixed_steps, seed, LanePass::Auto)
                .unwrap();
    });

    // Baseline: the same groups as separate homogeneous pools, run
    // back to back. Aggregate fps = total steps / total wall time.
    let mut homo_fps = vec![0.0f64; tasks.len()];
    for (i, (&task, &count)) in tasks.iter().zip(counts.iter()).enumerate() {
        let steps = rounds * count as u64;
        let mut fps = 0.0;
        b.run(&format!("table2h/homogeneous/{task}"), steps as f64, || {
            fps = run_throughput_lanes(
                task,
                "envpool-sync-vec",
                count,
                count,
                threads,
                steps,
                seed,
                LanePass::Auto,
            )
            .unwrap();
        });
        homo_fps[i] = fps;
    }
    let homo_secs: f64 = homo_fps
        .iter()
        .zip(counts.iter())
        .map(|(&fps, &count)| (rounds * count as u64) as f64 / fps)
        .sum();
    let agg_fps = mixed_steps as f64 / homo_secs;
    let ratio = mixed_fps / agg_fps;

    let mut t = Table::new(["Pool", "Envs", "env-steps/s"]);
    t.row([
        "mixed (1 pool, 3 groups)".to_string(),
        total.to_string(),
        fmt_fps(mixed_fps),
    ]);
    for (i, (&task, &count)) in tasks.iter().zip(counts.iter()).enumerate() {
        t.row([format!("homogeneous {task}"), count.to_string(), fmt_fps(homo_fps[i])]);
    }
    t.row(["homogeneous aggregate".to_string(), total.to_string(), fmt_fps(agg_fps)]);
    println!("{}", t.render());
    println!("  -> mixed / aggregate = {ratio:.3} (gate: >= 0.9, full mode only)");

    if !quick {
        assert!(
            ratio >= 0.9,
            "acceptance gate failed: mixed scenario pool at {mixed_fps:.0} env-steps/s is \
             {ratio:.3}x the homogeneous aggregate {agg_fps:.0} (need >= 0.9x)"
        );
    }

    b.write_snapshot("table2h").unwrap();
}
