//! Component microbenchmarks: the two queues and the end-to-end pool
//! round-trip — the quantities the paper's Appendix D optimizations
//! target (lock-free enqueue/dequeue, zero-copy block batching), plus
//! the ablation: EnvPool with a trivial Mutex<VecDeque> action queue,
//! quantifying what the lock-free design buys.

use envpool::bench_util::Bencher;
use envpool::pool::action_queue::ActionBufferQueue;
use envpool::pool::state_queue::StateBufferQueue;
use envpool::pool::{EnvPool, PoolConfig};
use std::collections::VecDeque;
use std::sync::Mutex;

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    let ops: usize = if quick { 20_000 } else { 1_000_000 };

    // --- ActionBufferQueue enqueue+dequeue round trip ---
    let q: ActionBufferQueue<u64> = ActionBufferQueue::new(256);
    b.run("queues/action_queue/roundtrip", ops as f64, || {
        for i in 0..ops as u64 {
            q.enqueue(i).unwrap();
            std::hint::black_box(q.try_dequeue());
        }
    });

    // --- ablation: Mutex<VecDeque> in the same role ---
    let mq: Mutex<VecDeque<u64>> = Mutex::new(VecDeque::with_capacity(256));
    b.run("queues/mutex_vecdeque/roundtrip", ops as f64, || {
        for i in 0..ops as u64 {
            mq.lock().unwrap().push_back(i);
            std::hint::black_box(mq.lock().unwrap().pop_front());
        }
    });

    // --- StateBufferQueue slot write + block recv (obs dim 16) ---
    let rounds = if quick { 2_000 } else { 100_000 };
    let sq = StateBufferQueue::new(8, 4, 16);
    let mut out = sq.make_output();
    b.run("queues/state_queue/block_cycle", (rounds * 4) as f64, || {
        for r in 0..rounds {
            for k in 0..4u32 {
                let t = sq.acquire();
                sq.write(t, k, r as f32, false, false, |obs| obs.fill(k as f32));
            }
            sq.recv_into(&mut out).unwrap();
        }
    });

    // --- whole-pool round trip on the cheapest env (overhead floor) ---
    let steps = if quick { 2_000 } else { 50_000 };
    let mut pool = EnvPool::make(
        PoolConfig::new("CartPole-v1").num_envs(6).batch_size(2).num_threads(2).seed(0),
    )
    .unwrap();
    pool.async_reset();
    let mut pout = pool.make_output();
    b.run("queues/pool/send_recv_cartpole", steps as f64, || {
        let mut done = 0usize;
        while done < steps {
            pool.recv_into(&mut pout).unwrap();
            let actions = vec![0.0f32; pout.len()];
            pool.send(&actions, &pout.env_ids.clone()).unwrap();
            done += pout.len();
        }
    });

    b.write_snapshot("queues").unwrap();
}
