//! Bench: Table 2j — the batched Atari emulator gate. Isolates the
//! emulator **tick pass** (the part PR 10 batched) from the pixel
//! pipeline: renders + preprocessing are ~30k byte ops per env-step and
//! dominate the end-to-end Atari cost, so an end-to-end ratio would
//! measure the (already-gated, Table 2g.3) slab pass and bury the tick
//! math in the noise floor.
//!
//! Timed paths, both over the same N=256 Pong games with identical
//! per-lane RNG streams and the same deterministic action tape,
//! resetting any finished game in place so all lanes stay live:
//!
//! - **scalar-lane**: `K` scalar [`Pong`] games ticked one lane at a
//!   time through `Game::tick` (the per-env reference path);
//! - **batched**: one [`PongLanes`] SoA batch ticked through masked
//!   lane-group passes ([`LaneGame::tick_pass`]) at widths 1/4/8 and at
//!   the auto-detected width.
//!
//! Because the pass is bitwise identical to the scalar tick, both paths
//! produce the *same trajectories* — the bench cross-checks reward/done
//! checksums so a rotted pass can't win the gate by computing garbage.
//!
//! Gate (full mode; `ENVPOOL_BENCH_QUICK=1` runs the shapes but skips
//! the assertion): batched at auto width >= 1.5x scalar-lane. End-to-end
//! `Pong-v5` forloop-vec rows (width 1 vs auto) are recorded for the
//! snapshot without a gate, as calibration context.

use envpool::bench_util::Bencher;
use envpool::coordinator::throughput::run_throughput_lanes;
use envpool::envs::atari::game::Game;
use envpool::envs::atari::pong::Pong;
use envpool::envs::vector::{LaneGame, PongLanes};
use envpool::metrics::table::{fmt_fps, Table};
use envpool::rng::Pcg32;
use envpool::simd::LanePass;

/// Lane count (Table 2's large-batch column).
const N: usize = 256;

/// Per-lane game RNG streams, keyed exactly as the engine keys them
/// (`preproc::game_rng`: seed ^ "ATAR", stream = env id).
fn game_rngs(seed: u64) -> Vec<Pcg32> {
    (0..N).map(|l| Pcg32::new(seed ^ 0x4154_4152, l as u64)).collect()
}

/// Deterministic `[tick, lane]` action tape shared by every timed path.
fn action_tape(ticks: usize) -> Vec<usize> {
    let mut rng = Pcg32::new(0xAC_7A9E, 1);
    (0..ticks * N).map(|_| rng.below(6) as usize).collect()
}

/// Reward/done checksum — rewards are small integers, so f64 summation
/// is exact and any cross-path divergence is a hard mismatch.
#[derive(PartialEq, Debug, Default)]
struct Checksum {
    reward: f64,
    dones: u64,
}

/// Tick the scalar reference lanes through the whole tape.
fn run_scalar(ticks: usize, tape: &[usize]) -> Checksum {
    let mut games: Vec<Pong> = (0..N).map(|_| Pong::new()).collect();
    let mut rngs = game_rngs(7);
    for (g, r) in games.iter_mut().zip(rngs.iter_mut()) {
        g.reset(r);
    }
    let mut sum = Checksum::default();
    for t in 0..ticks {
        for l in 0..N {
            let (rew, over) = games[l].tick(tape[t * N + l], &mut rngs[l]);
            sum.reward += rew as f64;
            if over {
                sum.dones += 1;
                games[l].reset(&mut rngs[l]);
            }
        }
    }
    sum
}

/// Tick the SoA batch through the whole tape at one lane-group width.
fn run_batched<const W: usize>(ticks: usize, tape: &[usize]) -> Checksum {
    let mut lanes = PongLanes::new(N);
    let mut rngs = game_rngs(7);
    for l in 0..N {
        lanes.reset_lane(l, &mut rngs[l]);
    }
    let step = vec![1u8; N];
    let mut rew = vec![0.0f32; N];
    let mut done = vec![0u8; N];
    let mut sum = Checksum::default();
    for t in 0..ticks {
        lanes.tick_pass::<W>(&tape[t * N..(t + 1) * N], &step, &mut rngs, &mut rew, &mut done);
        for l in 0..N {
            sum.reward += rew[l] as f64;
            if done[l] != 0 {
                sum.dones += 1;
                lanes.reset_lane(l, &mut rngs[l]);
            }
        }
    }
    sum
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    let ticks: usize = if quick { 500 } else { 20_000 };
    let tape = action_tape(ticks);
    let units = (ticks * N) as f64; // lane-ticks per invocation

    println!("== Table 2j: Pong emulator tick pass (N={N}, {ticks} ticks) lane-ticks/s ==");
    let mut ref_sum = Checksum::default();
    let rs = b.run("table2j/tick/scalar_lanes", units, || {
        ref_sum = run_scalar(ticks, &tape);
        std::hint::black_box(&ref_sum);
    });
    let mut rows = Vec::new();
    let mut by_width = |name: &str, w: usize| {
        let mut sum = Checksum::default();
        let r = b.run(&format!("table2j/tick/batched_w{w}{name}"), units, || {
            sum = match w {
                8 => run_batched::<8>(ticks, &tape),
                4 => run_batched::<4>(ticks, &tape),
                _ => run_batched::<1>(ticks, &tape),
            };
            std::hint::black_box(&sum);
        });
        assert_eq!(
            sum, ref_sum,
            "batched W={w} trajectories diverged from the scalar reference"
        );
        rows.push((format!("batched tick pass W={w}{name}"), r.throughput()));
        r
    };
    by_width("", 1);
    by_width("", 4);
    by_width("", 8);
    let auto_w = LanePass::Auto.width();
    let ra = by_width("_auto", auto_w);
    let gate = ra.throughput() / rs.throughput();

    let mut t = Table::new(["Path", "lane-ticks/s", "vs scalar-lane"]);
    t.row(["scalar-lane tick loop".into(), fmt_fps(rs.throughput()), "1.00x".into()]);
    for (name, tput) in &rows {
        t.row([name.clone(), fmt_fps(*tput), format!("{:.2}x", tput / rs.throughput())]);
    }
    println!("{}", t.render());

    // End-to-end context rows (no gate): the full Pong-v5 step with
    // renders + slab preprocessing, emulator at width 1 vs auto. The
    // expected delta here is small — see the module docs.
    let e2e_steps: u64 = if quick { 1_024 } else { 32_000 };
    println!("== Table 2j context: Pong-v5 forloop-vec N={N} end-to-end env-steps/s ==");
    for (tag, lp) in [("w1", LanePass::Scalar), ("auto", LanePass::Auto)] {
        b.run(&format!("table2j/e2e/forloop-vec_{tag}"), e2e_steps as f64, || {
            let f = run_throughput_lanes("Pong-v5", "forloop-vec", N, N, 1, e2e_steps, 0, lp)
                .unwrap();
            std::hint::black_box(f);
        });
    }

    b.write_snapshot("table2j").unwrap();

    if quick {
        println!("(quick mode: skipping the Table 2j acceptance assertion)");
    } else {
        assert!(
            gate >= 1.5,
            "acceptance gate failed: batched(auto W={auto_w})/scalar-lane = {gate:.2}x < 1.5x"
        );
        println!("acceptance gate OK: batched(auto W={auto_w})/scalar-lane = {gate:.2}x");
    }
}
