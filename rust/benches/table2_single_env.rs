//! Bench: Table 2 — single-environment (N=1) overhead: the baseline
//! executor vs EnvPool on Atari / MuJoCo / dm_control. The paper's point
//! is that even one env gets a speedup from eliminating the Python layer;
//! ours is that the pool adds negligible overhead over a bare for-loop
//! while the subprocess transport (the Python stand-in) pays heavily.

use envpool::bench_util::Bencher;
use envpool::coordinator::throughput::{frame_multiplier, run_throughput};
use envpool::metrics::table::{fmt_fps, Table};

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    let steps: u64 = if quick { 1_000 } else { 20_000 };

    println!("== Table 2: single-env (N=1) frames/s ==");
    let mut t = Table::new(["Task", "For-loop", "Subprocess", "EnvPool", "EnvPool/Subproc"]);
    for task in ["Pong-v5", "Ant-v4", "cheetah_run"] {
        let mut fl = 0.0;
        let mut sp = 0.0;
        let mut ep = 0.0;
        b.run(&format!("table2/{task}/forloop"), steps as f64, || {
            fl = run_throughput(task, "forloop", 1, 1, 1, steps, 0).unwrap();
        });
        b.run(&format!("table2/{task}/subprocess"), steps as f64, || {
            sp = run_throughput(task, "subprocess", 1, 1, 1, steps, 0).unwrap();
        });
        b.run(&format!("table2/{task}/envpool"), steps as f64, || {
            ep = run_throughput(task, "envpool-sync", 1, 1, 1, steps, 0).unwrap();
        });
        let _ = frame_multiplier(task);
        t.row([
            task.to_string(),
            fmt_fps(fl),
            fmt_fps(sp),
            fmt_fps(ep),
            format!("{:.2}x", ep / sp),
        ]);
    }
    println!("{}", t.render());
}
