//! Bench: Table 2 — single-environment (N=1) overhead: the baseline
//! executor vs EnvPool on Atari / MuJoCo / dm_control. The paper's point
//! is that even one env gets a speedup from eliminating the Python layer;
//! ours is that the pool adds negligible overhead over a bare for-loop
//! while the subprocess transport (the Python stand-in) pays heavily.

use envpool::bench_util::Bencher;
use envpool::coordinator::throughput::{frame_multiplier, run_throughput, run_throughput_lanes};
use envpool::metrics::table::{fmt_fps, Table};
use envpool::simd::LanePass;

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    let steps: u64 = if quick { 1_000 } else { 20_000 };

    println!("== Table 2: single-env (N=1) frames/s ==");
    let mut t = Table::new(["Task", "For-loop", "Subprocess", "EnvPool", "EnvPool/Subproc"]);
    for task in ["Pong-v5", "Ant-v4", "cheetah_run"] {
        let mut fl = 0.0;
        let mut sp = 0.0;
        let mut ep = 0.0;
        b.run(&format!("table2/{task}/forloop"), steps as f64, || {
            fl = run_throughput(task, "forloop", 1, 1, 1, steps, 0).unwrap();
        });
        b.run(&format!("table2/{task}/subprocess"), steps as f64, || {
            sp = run_throughput(task, "subprocess", 1, 1, 1, steps, 0).unwrap();
        });
        b.run(&format!("table2/{task}/envpool"), steps as f64, || {
            ep = run_throughput(task, "envpool-sync", 1, 1, 1, steps, 0).unwrap();
        });
        let _ = frame_multiplier(task);
        t.row([
            task.to_string(),
            fmt_fps(fl),
            fmt_fps(sp),
            fmt_fps(ep),
            format!("{:.2}x", ep / sp),
        ]);
    }
    println!("{}", t.render());

    // Cheap-env dispatch overhead: for classic control the env step is a
    // handful of flops, so per-env task dispatch dominates and the paper's
    // queues alone don't help — the chunked SoA backend
    // (`ExecMode::Vectorized`) is the fix. Acceptance gate for this
    // regime: vectorized ≥ 1.5× scalar on CartPole.
    let cheap_steps: u64 = if quick { 4_000 } else { 200_000 };
    let threads = 2usize;
    let n = 8 * threads;
    println!("== Table 2b: cheap-env (CartPole, N={n}) scalar vs vectorized env-steps/s ==");
    let mut t2 = Table::new(["Executor", "Scalar", "Vectorized", "Vec/Scalar"]);
    let mut gate_ratio = f64::NAN;
    for (label, scalar_kind, vec_kind) in [
        ("forloop", "forloop", "forloop-vec"),
        ("sample-factory", "sample-factory", "sample-factory-vec"),
        ("envpool-sync", "envpool-sync", "envpool-sync-vec"),
        ("envpool-async", "envpool-async", "envpool-async-vec"),
    ] {
        let mut sc = 0.0;
        let mut ve = 0.0;
        b.run(&format!("table2b/cartpole/{label}/scalar"), cheap_steps as f64, || {
            sc = run_throughput("CartPole-v1", scalar_kind, n, threads, threads, cheap_steps, 0)
                .unwrap();
        });
        b.run(&format!("table2b/cartpole/{label}/vectorized"), cheap_steps as f64, || {
            ve = run_throughput("CartPole-v1", vec_kind, n, threads, threads, cheap_steps, 0)
                .unwrap();
        });
        if label == "envpool-sync" {
            gate_ratio = ve / sc;
        }
        t2.row([label.to_string(), fmt_fps(sc), fmt_fps(ve), format!("{:.2}x", ve / sc)]);
    }
    println!("{}", t2.render());
    if quick {
        println!("(quick mode: skipping the 1.5x acceptance assertion)");
    } else {
        assert!(
            gate_ratio >= 1.5,
            "acceptance gate failed: envpool-sync vectorized/scalar = {gate_ratio:.2}x < 1.5x"
        );
        println!("acceptance gate OK: envpool-sync vectorized/scalar = {gate_ratio:.2}x");
    }

    // Table 2d — the SIMD lane pass: scalar-SoA (lane width 1, the
    // pre-SIMD kernel) vs forced widths 4 and 8 on CartPole, through
    // the bare vectorized executor (isolates the kernel from pool
    // dispatch; N large enough that kernel time dominates) and through
    // the vectorized pool (the deployed configuration). All widths are
    // bitwise identical (tests/simd_parity.rs), so this is a pure
    // throughput comparison. Acceptance gate: best SIMD width >= 1.5x
    // scalar-SoA on the bare executor.
    let simd_steps: u64 = if quick { 16_000 } else { 2_000_000 };
    let sn = 256usize;
    println!("== Table 2d: CartPole SoA kernel (N={sn}) SIMD lane pass env-steps/s ==");
    let mut t4 = Table::new(["Executor", "W=1 (scalar-SoA)", "W=4", "W=8", "best/W1"]);
    let mut simd_gate = f64::NAN;
    let auto_w = LanePass::Auto.width();
    println!("(auto lane width resolves to {auto_w} on this machine)");
    for (label, kind, n, threads) in [
        ("forloop-vec", "forloop-vec", sn, 1usize),
        ("envpool-sync-vec", "envpool-sync-vec", sn, 2),
    ] {
        let mut fps = [0.0f64; 3];
        for (i, lp) in [LanePass::Scalar, LanePass::Width4, LanePass::Width8]
            .into_iter()
            .enumerate()
        {
            b.run(&format!("table2d/cartpole/{label}/w{}", lp.width()), simd_steps as f64, || {
                let f = run_throughput_lanes(
                    "CartPole-v1", kind, n, n, threads, simd_steps, 0, lp,
                )
                .unwrap();
                fps[i] = fps[i].max(f);
            });
        }
        let best = fps[1].max(fps[2]);
        if label == "forloop-vec" {
            simd_gate = best / fps[0];
        }
        t4.row([
            label.to_string(),
            fmt_fps(fps[0]),
            fmt_fps(fps[1]),
            fmt_fps(fps[2]),
            format!("{:.2}x", best / fps[0]),
        ]);
    }
    println!("{}", t4.render());
    if quick {
        println!("(quick mode: skipping the SIMD 1.5x acceptance assertion)");
    } else {
        assert!(
            simd_gate >= 1.5,
            "acceptance gate failed: CartPole SIMD/scalar-SoA = {simd_gate:.2}x < 1.5x"
        );
        println!("acceptance gate OK: CartPole SIMD/scalar-SoA = {simd_gate:.2}x");
    }

    // Walker regime: the SoA kernel reuses the scalar solver per lane
    // (physics dominates), so the win is dispatch amortization and the
    // gate is "vectorized must not lose to scalar" — best-of-samples on
    // both sides, with a 3% allowance for timer noise — rather than the
    // cheap-env multiple above.
    let walker_steps: u64 = if quick { 2_000 } else { 50_000 };
    let wn = 8usize;
    let wt = 2usize;
    println!("== Table 2c: Walker (Hopper-v4, N={wn}) scalar vs vectorized env-steps/s ==");
    let mut t3 = Table::new(["Executor", "Scalar", "Vectorized", "Vec/Scalar"]);
    let mut walker_gate = f64::NAN;
    for (label, scalar_kind, vec_kind) in [
        ("forloop", "forloop", "forloop-vec"),
        ("envpool-sync", "envpool-sync", "envpool-sync-vec"),
        ("envpool-async", "envpool-async", "envpool-async-vec"),
    ] {
        let mut sc = 0.0f64;
        let mut ve = 0.0f64;
        b.run(&format!("table2c/hopper/{label}/scalar"), walker_steps as f64, || {
            let f = run_throughput("Hopper-v4", scalar_kind, wn, wt, wt, walker_steps, 0);
            sc = sc.max(f.unwrap());
        });
        b.run(&format!("table2c/hopper/{label}/vectorized"), walker_steps as f64, || {
            let f = run_throughput("Hopper-v4", vec_kind, wn, wt, wt, walker_steps, 0);
            ve = ve.max(f.unwrap());
        });
        if label == "envpool-sync" {
            walker_gate = ve / sc;
        }
        t3.row([label.to_string(), fmt_fps(sc), fmt_fps(ve), format!("{:.2}x", ve / sc)]);
    }
    println!("{}", t3.render());
    if quick {
        println!("(quick mode: skipping the walker vectorized >= scalar assertion)");
    } else {
        assert!(
            walker_gate >= 0.97,
            "acceptance gate failed: Hopper envpool-sync vectorized/scalar = \
             {walker_gate:.2}x < 0.97x (vectorized must not lose to scalar)"
        );
        println!("walker gate OK: envpool-sync vectorized/scalar = {walker_gate:.2}x");
    }

    // Table 2e — the lane-grouped constraint solver: the batch-resident
    // `WorldBatch` stepping Hopper lanes at width 1 (per-lane scalar
    // order — the bitwise reference, equivalent to the old
    // solver-per-lane path) vs forced widths 4/8, through the bare
    // vectorized executor so kernel time dominates (N=256, 1 thread).
    // Unlike Table 2d this is *not* a bitwise-identical knob: widths
    // > 1 run under the documented tolerance contract
    // (tests/mujoco_batch_parity.rs), so the gate buys throughput with
    // an explicitly budgeted numerics change. Acceptance gate: best
    // lane-grouped width >= 1.3x the width-1 path on forloop-vec.
    let mj_steps: u64 = if quick { 2_560 } else { 256_000 };
    let mn = 256usize;
    println!("== Table 2e: Walker (Hopper-v4, N={mn}) lane-grouped solver env-steps/s ==");
    let mut t5 = Table::new(["Executor", "W=1 (per-lane)", "W=4", "W=8", "best/W1"]);
    let mut solver_gate = f64::NAN;
    for (label, kind, threads) in
        [("forloop-vec", "forloop-vec", 1usize), ("envpool-sync-vec", "envpool-sync-vec", 2)]
    {
        let mut fps = [0.0f64; 3];
        for (i, lp) in [LanePass::Scalar, LanePass::Width4, LanePass::Width8]
            .into_iter()
            .enumerate()
        {
            b.run(&format!("table2e/hopper/{label}/w{}", lp.width()), mj_steps as f64, || {
                let f = run_throughput_lanes(
                    "Hopper-v4", kind, mn, mn, threads, mj_steps, 0, lp,
                )
                .unwrap();
                fps[i] = fps[i].max(f);
            });
        }
        let best = fps[1].max(fps[2]);
        if label == "forloop-vec" {
            solver_gate = best / fps[0];
        }
        t5.row([
            label.to_string(),
            fmt_fps(fps[0]),
            fmt_fps(fps[1]),
            fmt_fps(fps[2]),
            format!("{:.2}x", best / fps[0]),
        ]);
    }
    println!("{}", t5.render());
    if quick {
        println!("(quick mode: skipping the lane-grouped solver 1.3x acceptance assertion)");
    } else {
        assert!(
            solver_gate >= 1.3,
            "acceptance gate failed: Hopper lane-grouped/per-lane solver = \
             {solver_gate:.2}x < 1.3x"
        );
        println!("acceptance gate OK: Hopper lane-grouped/per-lane = {solver_gate:.2}x");
    }

    b.write_snapshot("table2").unwrap();
}
