//! Bench: Figure 3 — throughput vs number of worker threads for each
//! executor (the scaling curves). On this 1-core container the absolute
//! curves are flat; the measured quantity is per-step engine overhead
//! as the configuration scales (see DESIGN.md hardware note).

use envpool::bench_util::Bencher;
use envpool::coordinator::throughput::run_throughput;
use envpool::metrics::table::{fmt_fps, Table};

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    let steps: u64 = if quick { 1_000 } else { 8_000 };

    for task in ["Pong-v5", "Ant-v4"] {
        println!("== Figure 3: {task} FPS vs workers ==");
        let mut t = Table::new(["Workers", "Subprocess", "Sample-Factory", "EnvPool (sync)", "EnvPool (async)"]);
        for w in [1usize, 2, 4, 8] {
            let n = 3 * w;
            let mut sub = 0.0;
            let mut sf = 0.0;
            let mut sync = 0.0;
            let mut asy = 0.0;
            b.run(&format!("fig3/{task}/subprocess/w{w}"), steps as f64, || {
                sub = run_throughput(task, "subprocess", w, w, w, steps, 0).unwrap();
            });
            b.run(&format!("fig3/{task}/sample-factory/w{w}"), steps as f64, || {
                sf = run_throughput(task, "sample-factory", n, n, w, steps, 0).unwrap();
            });
            b.run(&format!("fig3/{task}/envpool-sync/w{w}"), steps as f64, || {
                sync = run_throughput(task, "envpool-sync", n, n, w, steps, 0).unwrap();
            });
            b.run(&format!("fig3/{task}/envpool-async/w{w}"), steps as f64, || {
                asy = run_throughput(task, "envpool-async", n, w, w, steps, 0).unwrap();
            });
            t.row([w.to_string(), fmt_fps(sub), fmt_fps(sf), fmt_fps(sync), fmt_fps(asy)]);
        }
        println!("{}", t.render());
    }

    b.write_snapshot("fig3").unwrap();
}
