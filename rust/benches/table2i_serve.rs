//! Bench: Table 2i — served-pool overhead (`envpool serve` / attach).
//!
//! CartPole, N = 256 envs. The in-process baseline steps a synchronous
//! scalar pool directly; the served runs move the same 256 envs into a
//! `PoolServer` and step them through `ShmClient`s — one client leasing
//! all 256 envs, then two concurrent clients leasing 128 each. Clients
//! pipeline up to two waves (ring credits) so the control-socket
//! round-trip overlaps env stepping, exactly how a trainer would drive
//! the attach surface.
//!
//! Acceptance gate (full mode only): the single attached client must
//! reach >= 0.9x the in-process pool — the slab copy + two control
//! frames per wave must cost less than 10% at CartPole wave rates.
//!
//! `cargo bench --bench table2i_serve` (ENVPOOL_BENCH_QUICK=1 for a fast
//! CI pass that skips the gate).

use envpool::bench_util::Bencher;
use envpool::config::ServeConfig;
use envpool::coordinator::throughput::run_throughput_lanes;
use envpool::executors::serve::PoolServer;
use envpool::executors::{ShmClient, VectorEnv};
use envpool::metrics::table::{fmt_fps, Table};
use envpool::simd::LanePass;
use std::path::PathBuf;

const N: usize = 256;
const SEED: u64 = 7;
const THREADS: usize = 4;

fn sock(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("envpool-t2i-{name}-{}.sock", std::process::id()))
}

/// Attach with a bounded retry: the bencher re-runs its closure for
/// warmup + sample iterations, and a lease freed by the previous
/// iteration's `detach` is re-admitted only once the server has drained
/// and reset it — a few milliseconds the next attach may race.
fn attach_retry(socket: &std::path::Path, k: usize) -> ShmClient {
    let t0 = std::time::Instant::now();
    loop {
        match ShmClient::attach(socket, k) {
            Ok(c) => return c,
            Err(e) => {
                assert!(
                    t0.elapsed() < std::time::Duration::from_secs(10),
                    "attach never admitted: {e}"
                );
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
        }
    }
}

/// Step `rounds` waves through an attached client, keeping up to two
/// waves in flight (bounded by the ring credits).
fn drive(client: &mut ShmClient, rounds: u64) {
    let k = client.num_envs();
    let mut out = client.make_output();
    client.reset(&mut out).expect("reset");
    let acts: Vec<f32> = (0..k).map(|i| (i % 2) as f32).collect();
    let depth = client.max_outstanding().min(2) as u64;
    let mut sent = 0u64;
    let mut recvd = 0u64;
    while sent < depth.min(rounds) {
        client.send_wave(&acts).expect("send");
        sent += 1;
    }
    while recvd < rounds {
        client.recv_wave(&mut out).expect("recv");
        recvd += 1;
        if sent < rounds {
            client.send_wave(&acts).expect("send");
            sent += 1;
        }
    }
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    let rounds: u64 = if quick { 64 } else { 2_000 };
    let steps = rounds * N as u64;

    println!("== Table 2i: served pool (serve/attach) vs in-process ==");
    println!("(CartPole-v1, {N} envs, {THREADS} pool threads, {rounds} waves)");

    // In-process baseline: the same envs, stepped without a wire.
    let mut base_fps = 0.0;
    b.run("table2i/in-process/sync-256", steps as f64, || {
        base_fps = run_throughput_lanes(
            "CartPole-v1",
            "envpool-sync",
            N,
            N,
            THREADS,
            steps,
            SEED,
            LanePass::Auto,
        )
        .unwrap();
    });

    // Served, one client leasing all 256 envs.
    let mut one_fps = 0.0;
    {
        let cfg = ServeConfig::new("CartPole-v1", sock("one"))
            .max_clients(1)
            .lease_size(N)
            .num_threads(THREADS)
            .seed(SEED);
        let server = PoolServer::start(cfg).expect("server");
        let mut client = ShmClient::attach(server.socket_path(), N).expect("attach");
        let mut elapsed = 0.0;
        b.run("table2i/served/1x256", steps as f64, || {
            let t0 = std::time::Instant::now();
            drive(&mut client, rounds);
            elapsed = t0.elapsed().as_secs_f64();
        });
        one_fps = steps as f64 / elapsed;
        client.detach().expect("detach");
        server.stop();
    }

    // Served, two concurrent clients leasing 128 envs each.
    let mut two_fps = 0.0;
    {
        let cfg = ServeConfig::new("CartPole-v1", sock("two"))
            .max_clients(2)
            .lease_size(N / 2)
            .num_threads(THREADS)
            .seed(SEED);
        let server = PoolServer::start(cfg).expect("server");
        let mut elapsed = 0.0;
        b.run("table2i/served/2x128", steps as f64, || {
            let clients: Vec<ShmClient> =
                (0..2).map(|_| attach_retry(server.socket_path(), N / 2)).collect();
            let t0 = std::time::Instant::now();
            let handles: Vec<_> = clients
                .into_iter()
                .map(|mut c| std::thread::spawn(move || {
                    drive(&mut c, rounds);
                    c.detach().expect("detach");
                }))
                .collect();
            for h in handles {
                h.join().expect("client thread");
            }
            elapsed = t0.elapsed().as_secs_f64();
        });
        two_fps = steps as f64 / elapsed;
        server.stop();
    }

    let ratio_one = one_fps / base_fps;
    let ratio_two = two_fps / base_fps;
    let mut t = Table::new(["Pool", "Clients x envs", "env-steps/s", "vs in-process"]);
    t.row([
        "in-process".to_string(),
        format!("- x {N}"),
        fmt_fps(base_fps),
        "1.000".to_string(),
    ]);
    t.row([
        "served".to_string(),
        format!("1 x {N}"),
        fmt_fps(one_fps),
        format!("{ratio_one:.3}"),
    ]);
    t.row([
        "served".to_string(),
        format!("2 x {}", N / 2),
        fmt_fps(two_fps),
        format!("{ratio_two:.3}"),
    ]);
    println!("{}", t.render());
    println!("  -> served(1x{N}) / in-process = {ratio_one:.3} (gate: >= 0.9, full mode only)");

    if !quick {
        assert!(
            ratio_one >= 0.9,
            "acceptance gate failed: attached client at {one_fps:.0} env-steps/s is \
             {ratio_one:.3}x the in-process pool {base_fps:.0} (need >= 0.9x)"
        );
    }

    b.write_snapshot("table2i").unwrap();
}
