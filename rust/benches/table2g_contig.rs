//! Bench: Table 2g — the contiguous-hot-path gates. Three layout
//! levers, one per row block, each with its own acceptance gate
//! (asserted in full mode; `ENVPOOL_BENCH_QUICK=1` runs the shapes but
//! skips the timing assertions):
//!
//! 1. **Body-major vs lane-major lane groups** (gate: contiguous >=
//!    1.15x strided). The lane-major `WorldBatch` layout no longer
//!    exists in the library — the body-major rewrite replaced it — so
//!    the strided baseline is a reference microkernel in this file:
//!    the same solver-shaped lane-group sweep (load pos/vel groups per
//!    body, integrate, store) over a `[body * lanes + lane]` slab
//!    (contiguous `F32s` loads, what `WorldBatch` does today) and over
//!    a `[lane * bodies + body]` slab (per-lane stride gathers, what
//!    the pre-rewrite layout forced). The end-to-end body-major solver
//!    (Hopper forloop-vec N=256, Table 2e's subject) is also recorded
//!    for the snapshot, without a gate — its old-layout baseline is
//!    gone by construction.
//! 2. **Blocked transposed-weights GEMM vs sequential axpy GEMV**
//!    (gate: >= 1.5x at the f32 forward shape, batch 256): the exact
//!    two routines the f32 policy forward switched between —
//!    [`gemm_bt_f32`] vs [`affine_f32`].
//! 3. **SoA Atari preprocessing vs per-lane** (gate: forloop-vec >=
//!    1.3x forloop on Pong N=64): the slab-resident `AtariVec` pixel
//!    pass vs `K` scalar envs, through the bare vectorized executor so
//!    preprocessing (which dominates the Atari-like step: ~28k native
//!    pixels of max-pool + downsample per frame vs hundreds of
//!    emulator ops) is the differentiator.

use envpool::bench_util::Bencher;
use envpool::coordinator::throughput::{run_throughput, run_throughput_lanes};
use envpool::metrics::table::{fmt_fps, Table};
use envpool::runtime::native::affine_f32;
use envpool::simd::{gemm_bt_f32, F32s, LanePass};

/// Hopper-ish rigid-body count for the layout microkernel.
const BODIES: usize = 13;
/// Lane width of the microkernel groups (one AVX register).
const W: usize = 8;

/// Solver-shaped sweep over a **body-major** slab: every lane group is
/// one contiguous `F32s` load/store, exactly like `WorldBatch`'s
/// `ldc`/`stc` helpers.
fn sweep_body_major(pos: &mut [f32], vel: &[f32], lanes: usize) {
    for b in 0..BODIES {
        let base = b * lanes;
        let mut g = 0;
        while g < lanes {
            let n = (lanes - g).min(W);
            let p = F32s::<W>::load_or(&pos[base + g..base + g + n], 0.0);
            let v = F32s::<W>::load_or(&vel[base + g..base + g + n], 0.0);
            let r = p + v * F32s::splat(2e-3) + p * F32s::splat(-1e-4);
            pos[base + g..base + g + n].copy_from_slice(&r.0[..n]);
            g += W;
        }
    }
}

/// The same sweep over a **lane-major** slab (`[lane * BODIES + body]`,
/// the pre-rewrite layout): each lane group is a stride-`BODIES` gather
/// and scatter.
fn sweep_lane_major(pos: &mut [f32], vel: &[f32], lanes: usize) {
    for b in 0..BODIES {
        let mut g = 0;
        while g < lanes {
            let n = (lanes - g).min(W);
            let p = F32s::<W>::from_fn(|i| if i < n { pos[(g + i) * BODIES + b] } else { 0.0 });
            let v = F32s::<W>::from_fn(|i| if i < n { vel[(g + i) * BODIES + b] } else { 0.0 });
            let r = p + v * F32s::splat(2e-3) + p * F32s::splat(-1e-4);
            for i in 0..n {
                pos[(g + i) * BODIES + b] = r.0[i];
            }
            g += W;
        }
    }
}

/// Deterministic non-zero fill (zeros would let `affine_f32`'s
/// skip-zero fast path distort the GEMV baseline).
fn fill(buf: &mut [f32], salt: u32) {
    for (i, v) in buf.iter_mut().enumerate() {
        let h = (i as u32).wrapping_add(salt).wrapping_mul(2_654_435_761);
        *v = ((h >> 8) % 2000) as f32 / 1000.0 - 0.9995;
    }
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();

    // --- 2g.1: body-major vs lane-major lane-group sweep ---
    let lanes = 4096usize; // big enough that layout, not loop overhead, shows
    let sweeps: usize = if quick { 200 } else { 20_000 };
    let mut pos_bm = vec![0.0f32; BODIES * lanes];
    let mut vel_bm = vec![0.0f32; BODIES * lanes];
    let mut pos_lm = vec![0.0f32; BODIES * lanes];
    let mut vel_lm = vec![0.0f32; BODIES * lanes];
    fill(&mut pos_bm, 1);
    fill(&mut vel_bm, 2);
    fill(&mut pos_lm, 1);
    fill(&mut vel_lm, 2);
    let units = (sweeps * BODIES * lanes) as f64;
    println!("== Table 2g.1: lane-group sweep ({BODIES} bodies x {lanes} lanes, W={W}) ==");
    let rb = b.run("table2g/layout/body_major", units, || {
        for _ in 0..sweeps {
            sweep_body_major(&mut pos_bm, &vel_bm, lanes);
        }
        std::hint::black_box(&pos_bm);
    });
    let rl = b.run("table2g/layout/lane_major", units, || {
        for _ in 0..sweeps {
            sweep_lane_major(&mut pos_lm, &vel_lm, lanes);
        }
        std::hint::black_box(&pos_lm);
    });
    let layout_gate = rb.throughput() / rl.throughput();
    println!("body-major/lane-major = {layout_gate:.2}x");

    // End-to-end body-major solver for the snapshot record (no gate —
    // the lane-major solver it replaced is gone; Table 2e gates this
    // path against its own width-1 reference).
    let mj_steps: u64 = if quick { 2_560 } else { 128_000 };
    let mn = 256usize;
    let mut t1 = Table::new(["Path", "env-steps/s"]);
    for lp in [LanePass::Width4, LanePass::Width8] {
        let mut fps = 0.0f64;
        b.run(&format!("table2g/hopper_e2e/forloop-vec/w{}", lp.width()), mj_steps as f64, || {
            let f =
                run_throughput_lanes("Hopper-v4", "forloop-vec", mn, mn, 1, mj_steps, 0, lp)
                    .unwrap();
            fps = fps.max(f);
        });
        t1.row([format!("body-major solver W={}", lp.width()), fmt_fps(fps)]);
    }
    println!("{}", t1.render());

    // --- 2g.2: blocked transposed GEMM vs sequential axpy GEMV ---
    // The f32 forward's hidden-layer shape: batch 256, 64 -> 64.
    let (bsz, d_in, d_out) = (256usize, 64usize, 64usize);
    let reps: usize = if quick { 50 } else { 5_000 };
    let mut x = vec![0.0f32; bsz * d_in];
    let mut w = vec![0.0f32; d_in * d_out]; // [d_in, d_out] — GEMV layout
    let mut wt = vec![0.0f32; d_out * d_in]; // [d_out, d_in] — GEMM layout
    let mut bias = vec![0.0f32; d_out];
    fill(&mut x, 3);
    fill(&mut w, 4);
    fill(&mut bias, 5);
    for k in 0..d_in {
        for o in 0..d_out {
            wt[o * d_in + k] = w[k * d_out + o];
        }
    }
    let mut out = vec![0.0f32; bsz * d_out];
    let gunits = (reps * bsz * d_in * d_out) as f64; // MACs
    println!("== Table 2g.2: f32 forward matmul ({bsz}x{d_in} @ {d_in}x{d_out}) MACs/s ==");
    let rg = b.run("table2g/matmul/gemm_bt", gunits, || {
        for _ in 0..reps {
            gemm_bt_f32(&x, &wt, &bias, &mut out, bsz, d_in, d_out);
        }
        std::hint::black_box(&out);
    });
    let rv = b.run("table2g/matmul/axpy_gemv", gunits, || {
        for _ in 0..reps {
            affine_f32(&x, &w, &bias, &mut out, bsz, d_in, d_out);
        }
        std::hint::black_box(&out);
    });
    let gemm_gate = rg.throughput() / rv.throughput();
    println!("gemm_bt/axpy_gemv = {gemm_gate:.2}x");

    // --- 2g.3: SoA Atari preprocessing vs per-lane ---
    let an = 64usize;
    let asteps: u64 = if quick { 1_024 } else { 32_000 };
    println!("== Table 2g.3: Pong (N={an}) slab SoA preproc vs per-lane env-steps/s ==");
    let mut fl = 0.0f64;
    let mut ve = 0.0f64;
    b.run("table2g/pong/forloop", asteps as f64, || {
        let f = run_throughput("Pong-v5", "forloop", an, an, 1, asteps, 0).unwrap();
        fl = fl.max(f);
    });
    b.run("table2g/pong/forloop-vec", asteps as f64, || {
        let f = run_throughput("Pong-v5", "forloop-vec", an, an, 1, asteps, 0).unwrap();
        ve = ve.max(f);
    });
    let atari_gate = ve / fl;
    let mut t3 = Table::new(["Path", "frames/s", "vs per-lane"]);
    t3.row(["per-lane (forloop)".into(), fmt_fps(fl), "1.00x".into()]);
    t3.row(["slab SoA (forloop-vec)".into(), fmt_fps(ve), format!("{atari_gate:.2}x")]);
    println!("{}", t3.render());

    b.write_snapshot("table2g").unwrap();

    if quick {
        println!("(quick mode: skipping the three Table 2g acceptance assertions)");
    } else {
        assert!(
            layout_gate >= 1.15,
            "acceptance gate failed: body-major/lane-major sweep = {layout_gate:.2}x < 1.15x"
        );
        assert!(
            gemm_gate >= 1.5,
            "acceptance gate failed: gemm_bt/axpy_gemv = {gemm_gate:.2}x < 1.5x"
        );
        assert!(
            atari_gate >= 1.3,
            "acceptance gate failed: Pong slab-SoA/per-lane = {atari_gate:.2}x < 1.3x"
        );
        println!(
            "acceptance gates OK: layout {layout_gate:.2}x, gemm {gemm_gate:.2}x, \
             atari {atari_gate:.2}x"
        );
    }
}
