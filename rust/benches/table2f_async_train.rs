//! Bench: Table 2f — end-to-end training throughput, synchronous PPO
//! loop vs the decoupled async actor–learner loop (`--async-train`) on
//! CartPole at N=256.
//!
//! The sync loop's wall clock is `T×(inference + env_step + store) +
//! GAE + updates` — every phase waits on every other. The async loop
//! hides the env-step term: pool workers step continuously while the
//! coordinator runs inference and the learner, and its `recv_wait`
//! profile bar is the only residual. The table reports env-steps/s for
//! both loops plus the async run's measured policy lag, and (full mode
//! only) asserts the acceptance gate: async >= 1.5x sync.
//!
//! `ENVPOOL_BENCH_QUICK=1` shrinks rounds/samples for CI smoke and
//! skips the gate (timing assertions are meaningless on loaded shared
//! runners).

use envpool::bench_util::Bencher;
use envpool::config::{BackendKind, ExecutorKind, TrainConfig};
use envpool::coordinator::ppo;
use envpool::metrics::table::{fmt_fps, Table};
use envpool::metrics::timer::Category;

fn main() {
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    // Full train runs per sample are expensive; keep sampling light.
    let b = Bencher::new(if quick { 1 } else { 3 }, if quick { 0 } else { 1 });

    let n = 256usize;
    let t_len = 32usize;
    let rounds: u64 = if quick { 2 } else { 12 };
    let total_steps = rounds * (n * t_len) as u64;
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2).clamp(2, 8);

    let base = TrainConfig {
        env_id: "CartPole-v1".into(),
        backend: BackendKind::Native,
        num_envs: n,
        batch_size: n,
        num_threads: threads,
        num_steps: t_len,
        total_steps,
        seed: 7,
        ..TrainConfig::default()
    };

    let sync_cfg = TrainConfig { executor: ExecutorKind::EnvPoolSync, ..base.clone() };
    // Async mode: recv waits for the fastest N/4 envs (paper §3.2);
    // scalar exec so the comparison isolates the training loop, not the
    // chunked kernels.
    let async_cfg = TrainConfig {
        executor: ExecutorKind::EnvPoolAsync,
        batch_size: n / 4,
        async_train: true,
        ..base.clone()
    };

    println!("== Table 2f: CartPole (N={n}, T={t_len}, {threads} threads) train env-steps/s ==");
    let mut sync_fps = 0.0f64;
    b.run("table2f/cartpole/sync-train", total_steps as f64, || {
        let (s, _) = ppo::train_profiled(&sync_cfg).unwrap();
        sync_fps = sync_fps.max(s.env_steps as f64 / s.wall_secs);
    });
    let mut async_fps = 0.0f64;
    let mut lag_line = String::from("n/a");
    let mut recv_frac = 0.0f64;
    b.run("table2f/cartpole/async-train", total_steps as f64, || {
        let (s, prof) = ppo::train_profiled(&async_cfg).unwrap();
        async_fps = async_fps.max(s.env_steps as f64 / s.wall_secs);
        if let (Some(mean), Some(max)) = (s.policy_lag_mean, s.policy_lag_max) {
            lag_line = format!("mean {mean:.2} / max {max}");
        }
        recv_frac = prof.fraction(Category::RecvWait);
    });

    let ratio = async_fps / sync_fps;
    let mut t = Table::new(["Loop", "env-steps/s", "vs sync", "policy lag (updates)"]);
    t.row(["sync (envpool-sync)".into(), fmt_fps(sync_fps), "1.00x".into(), "on-policy".into()]);
    t.row([
        format!("async (envpool-async, M=N/4)"),
        fmt_fps(async_fps),
        format!("{ratio:.2}x"),
        lag_line,
    ]);
    println!("{}", t.render());
    println!("async coordinator recv_wait fraction: {:.1}%", 100.0 * recv_frac);

    if quick {
        println!("(quick mode: skipping the async-train 1.5x acceptance assertion)");
    } else {
        assert!(
            ratio >= 1.5,
            "acceptance gate failed: async-train/sync-train = {ratio:.2}x < 1.5x"
        );
        println!("acceptance gate OK: async-train/sync-train = {ratio:.2}x");
    }

    b.write_snapshot("table2f").unwrap();
}
