//! Bench: Table 1 — end-to-end simulation throughput per executor on
//! Atari-like and MuJoCo-like tasks. `cargo bench --bench table1_throughput`
//! (set ENVPOOL_BENCH_QUICK=1 for a fast pass).

use envpool::bench_util::Bencher;
use envpool::coordinator::throughput::run_throughput;

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("ENVPOOL_BENCH_QUICK").is_ok();
    let steps: u64 = if quick { 1_000 } else { 10_000 };
    let threads = 2usize;
    let n = 3 * threads;

    println!("== Table 1: simulation throughput (frames/s incl. frameskip) ==");
    println!(
        "(vectorized rows use the SIMD lane pass; auto lane width = {} on this machine)",
        envpool::simd::LanePass::Auto.width()
    );
    // CartPole rides along to cover the cheap-env regime where the
    // chunked SoA backend (the `*-vec` rows) is the differentiator.
    for task in ["Pong-v5", "Ant-v4", "CartPole-v1"] {
        for (label, kind, ne, bs) in [
            ("forloop", "forloop", n, n),
            ("forloop-vec", "forloop-vec", n, n),
            ("subprocess", "subprocess", threads, threads),
            ("sample-factory", "sample-factory", n, n),
            ("sample-factory-vec", "sample-factory-vec", n, n),
            ("envpool-sync", "envpool-sync", n, n),
            ("envpool-sync-vec", "envpool-sync-vec", n, n),
            ("envpool-async", "envpool-async", n, threads),
            ("envpool-async-vec", "envpool-async-vec", n, threads),
            // NUMA-sharded rows (2 logical shards; see throughput::NUMA_NODES):
            // n = 3*threads is even and threads = 2, so everything divides.
            ("envpool-numa-async", "envpool-numa-async", n, threads),
            ("envpool-numa-async-vec", "envpool-numa-async-vec", n, threads),
        ] {
            // one bench sample = `steps` env steps; report fps separately
            let mut fps = 0.0;
            b.run(&format!("table1/{task}/{label}"), steps as f64, || {
                fps = run_throughput(task, kind, ne, bs, threads, steps, 0).unwrap();
            });
            let mult = envpool::coordinator::throughput::frame_multiplier(task);
            println!("  -> {task}/{label}: {fps:.0} frames/s ({:.0} env-steps/s)", fps / mult as f64);
        }
    }

    b.write_snapshot("table1").unwrap();
}
